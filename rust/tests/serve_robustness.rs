//! End-to-end robustness tests for `thanos serve` (DESIGN.md §Serving):
//! batched answers are bitwise the unbatched forward pass, overload is
//! shed explicitly, a poisoned batch fails only its own requests, a
//! corrupt hot-reload candidate is rejected while the old model keeps
//! answering, and a valid candidate swaps without dropping in-flight
//! work.
//!
//! The fault-injection schedule is process-global (`robust::faults`),
//! so every test here serializes on [`TEST_LOCK`] — including the ones
//! that install no schedule, because a concurrent test's schedule
//! would otherwise fire at *their* `serve.*` sites.

use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use thanos::config::ModelConfig;
use thanos::linalg::Mat;
use thanos::model::ModelState;
use thanos::pruning::{magnitude, Pattern};
use thanos::robust::faults;
use thanos::runtime::{ModelManifest, ParamEntry};
use thanos::serve::{Response, ServeClient, ServeOptions, Server, Status};
use thanos::sparse::SparseModel;

static TEST_LOCK: Mutex<()> = Mutex::new(());

fn lock_tests() -> std::sync::MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The micro model from the checkpoint-corruption suite: d_model=8, so
/// the serving chain is 8 → 8 and every request is 8 floats.
fn micro_manifest() -> ModelManifest {
    let cfg = ModelConfig {
        name: "micro".into(),
        vocab: 16,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_ff: 16,
        seq_len: 4,
    };
    let mut layout = Vec::new();
    let mut off = 0usize;
    let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
        let numel: usize = shape.iter().product();
        layout.push(ParamEntry { name: name.into(), offset: *off, shape });
        *off += numel;
    };
    push(&mut layout, "emb", vec![16, 8], &mut off);
    push(&mut layout, "pos", vec![4, 8], &mut off);
    let mut block_flat = 0;
    for l in 0..cfg.n_layers {
        let before = off;
        push(&mut layout, &format!("blocks.{l}.ln1"), vec![8], &mut off);
        for w in ["wq", "wk", "wv", "wo"] {
            push(&mut layout, &format!("blocks.{l}.{w}"), vec![8, 8], &mut off);
        }
        push(&mut layout, &format!("blocks.{l}.ln2"), vec![8], &mut off);
        push(&mut layout, &format!("blocks.{l}.w1"), vec![16, 8], &mut off);
        push(&mut layout, &format!("blocks.{l}.w2"), vec![8, 16], &mut off);
        block_flat = off - before;
    }
    push(&mut layout, "ln_f", vec![8], &mut off);
    ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
}

/// A 2:4-pruned micro state + its compressed model; different seeds
/// give different weights (distinct "checkpoint generations").
fn pruned(seed: u64) -> (ModelState, SparseModel) {
    let mm = micro_manifest();
    let mut st = ModelState::init(&mm, seed);
    for l in 0..mm.config.n_layers {
        for name in st.prunable_layers(l) {
            let w = st.get_mat(&name).unwrap();
            st.set_mat(&name, &magnitude::semi_structured(&w, 2, 4).w).unwrap();
        }
    }
    let pattern = Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 };
    let sm = SparseModel::compress_state(&st, &pattern).unwrap();
    (st, sm)
}

fn probe_input(tag: usize) -> Vec<f32> {
    (0..8).map(|i| ((tag * 31 + i) as f32 * 0.37).sin()).collect()
}

/// The unbatched forward pass — what every served answer must equal
/// bitwise (column independence of the sparse kernels).
fn oracle(sm: &SparseModel, input: &[f32]) -> Vec<f32> {
    sm.forward_batch(&Mat::from_vec(input.len(), 1, input.to_vec())).unwrap().data
}

fn assert_bitwise(resp: &Response, expect: &[f32], what: &str) {
    assert_eq!(resp.status, Status::Ok, "{what}: {:?} ({})", resp.status, resp.reason);
    assert_eq!(resp.output.len(), expect.len(), "{what}: output length");
    for (i, (a, b)) in resp.output.iter().zip(expect).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: element {i} differs");
    }
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("thanos-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

#[test]
fn concurrent_responses_are_bitwise_the_unbatched_forward() {
    let _g = lock_tests();
    faults::clear();
    let (_st, sm) = pruned(7);
    let opts = ServeOptions { max_batch: 8, batch_window_ms: 10, ..Default::default() };
    let server = Server::start(sm.clone(), "oracle-test", opts).unwrap();
    let addr = server.local_addr();

    let handles: Vec<_> = (0..8)
        .map(|t| {
            let sm = sm.clone();
            std::thread::spawn(move || {
                let mut c = ServeClient::connect(addr).unwrap();
                for r in 0..4 {
                    let input = probe_input(t * 100 + r);
                    let resp = c.infer(&input, 0).unwrap();
                    assert_bitwise(&resp, &oracle(&sm, &input), "concurrent request");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let snap = server.snapshot();
    assert_eq!(snap.completed, 32, "all requests answered");
    assert_eq!(snap.shed, 0);
    assert_eq!(snap.batch_failed, 0);
    assert_eq!(snap.deadline_dropped, 0);
    assert!(snap.batches >= 4, "32 requests over max_batch=8 need >= 4 batches");
    assert!(snap.p99_ms > 0.0, "latency histogram must have recorded");

    // Wrong input dimension is a per-request BadRequest, not a hangup.
    let mut c = ServeClient::connect(addr).unwrap();
    let bad = c.infer(&[1.0, 2.0, 3.0], 0).unwrap();
    assert_eq!(bad.status, Status::BadRequest);
    assert!(bad.reason.contains("input dim 3"), "reason: {}", bad.reason);
    let good = c.infer(&probe_input(9), 0).unwrap();
    assert_eq!(good.status, Status::Ok, "connection survives a bad request");
}

#[test]
fn queue_overflow_sheds_with_explicit_reason() {
    let _g = lock_tests();
    faults::clear();
    let (_st, sm) = pruned(7);
    let opts = ServeOptions {
        queue_cap: 2,
        max_batch: 64,
        batch_window_ms: 500,
        ..Default::default()
    };
    let server = Server::start(sm, "shed-test", opts).unwrap();
    let addr = server.local_addr();

    // 5 clients fire simultaneously into a 2-slot queue whose batcher
    // holds its flush for 500 ms: exactly 2 ride the batch, 3 shed.
    let barrier = Arc::new(Barrier::new(5));
    let handles: Vec<_> = (0..5)
        .map(|t| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = ServeClient::connect(addr).unwrap();
                barrier.wait();
                c.infer(&probe_input(t), 0).unwrap()
            })
        })
        .collect();
    let results: Vec<Response> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let ok = results.iter().filter(|r| r.status == Status::Ok).count();
    let shed: Vec<&Response> =
        results.iter().filter(|r| r.status == Status::Shed).collect();
    assert_eq!(ok, 2, "queue capacity admits exactly 2");
    assert_eq!(shed.len(), 3, "the other 3 must shed");
    for r in &shed {
        assert!(
            r.reason.contains("queue full (capacity 2)"),
            "shed reason must name the bound, got: {}",
            r.reason
        );
    }
    assert_eq!(server.snapshot().shed, 3);
}

#[test]
fn batch_panic_fails_its_requests_not_the_daemon() {
    let _g = lock_tests();
    faults::clear();
    faults::install(faults::parse_schedule("serve.batch:1=panic").unwrap());
    let (_st, sm) = pruned(7);
    let server = Server::start(sm.clone(), "panic-test", Default::default()).unwrap();
    let mut c = ServeClient::connect(server.local_addr()).unwrap();

    let input = probe_input(1);
    let r1 = c.infer(&input, 0).unwrap();
    assert_eq!(r1.status, Status::BatchFailed, "poisoned batch fails its riders");
    assert!(r1.reason.contains("panic"), "reason: {}", r1.reason);

    // Same connection, next request: the daemon is alive and correct.
    let r2 = c.infer(&input, 0).unwrap();
    assert_bitwise(&r2, &oracle(&sm, &input), "request after contained panic");

    let snap = server.snapshot();
    assert_eq!(snap.batch_failed, 1);
    assert_eq!(snap.completed, 1);
    faults::clear();
}

#[test]
fn expired_deadline_is_cancelled_at_the_flush_boundary() {
    let _g = lock_tests();
    faults::clear();
    let (_st, sm) = pruned(7);
    let opts = ServeOptions { batch_window_ms: 200, ..Default::default() };
    let server = Server::start(sm, "deadline-test", opts).unwrap();
    let mut c = ServeClient::connect(server.local_addr()).unwrap();

    // 5 ms budget against a 200 ms batching window: expired by flush.
    let r = c.infer(&probe_input(2), 5).unwrap();
    assert_eq!(r.status, Status::DeadlineExceeded, "reason: {}", r.reason);
    assert!(r.reason.contains("deadline exceeded"), "reason: {}", r.reason);
    assert_eq!(server.snapshot().deadline_dropped, 1);
}

#[test]
fn corrupt_reload_candidate_is_rejected_while_serving_continues() {
    let _g = lock_tests();
    faults::clear();
    let watch = temp_dir("corrupt-watch");
    let staging = temp_dir("corrupt-staging");

    let (_st_a, sm_a) = pruned(7);
    let opts = ServeOptions {
        watch_dir: Some(watch.clone()),
        poll_ms: 20,
        batch_window_ms: 5,
        ..Default::default()
    };
    let server = Server::start(sm_a.clone(), "A", opts).unwrap();
    let mut c = ServeClient::connect(server.local_addr()).unwrap();

    // A valid v3 candidate with one flipped bit: the CRC loader must
    // reject it (ckpt_corruption.rs proves every flip is caught).
    let (st_b, sm_b) = pruned(13);
    let valid = staging.join("b.thnck");
    st_b.save_compressed(&valid, &sm_b).unwrap();
    let mut bytes = std::fs::read(&valid).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 1;
    std::fs::write(watch.join("bad.thnck"), &bytes).unwrap();

    // Hammer the server while the watcher trips over the candidate:
    // every answer keeps coming from model A.
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut saw_rejection = false;
    let mut tag = 0usize;
    while Instant::now() < deadline {
        let input = probe_input(tag);
        tag += 1;
        let r = c.infer(&input, 0).unwrap();
        assert_bitwise(&r, &oracle(&sm_a, &input), "request during corrupt reload");
        if server.snapshot().reloads_rejected >= 1 {
            saw_rejection = true;
            break;
        }
    }
    assert!(saw_rejection, "watcher never rejected the corrupt candidate");

    let snap = server.snapshot();
    assert_eq!(snap.reloads_ok, 0);
    assert_eq!(snap.model_version, 1, "old model must still be serving");
    let input = probe_input(999);
    let r = c.infer(&input, 0).unwrap();
    assert_bitwise(&r, &oracle(&sm_a, &input), "request after rejected reload");

    let _ = std::fs::remove_dir_all(&watch);
    let _ = std::fs::remove_dir_all(&staging);
}

#[test]
fn valid_reload_swaps_without_dropping_requests() {
    let _g = lock_tests();
    faults::clear();
    let watch = temp_dir("valid-watch");

    let (_st_a, sm_a) = pruned(7);
    let (st_b, sm_b) = pruned(13);
    // The generations must be distinguishable for the post-swap check.
    let probe = probe_input(5);
    assert_ne!(
        oracle(&sm_a, &probe)
            .iter()
            .map(|v| v.to_bits())
            .collect::<Vec<_>>(),
        oracle(&sm_b, &probe)
            .iter()
            .map(|v| v.to_bits())
            .collect::<Vec<_>>(),
        "seeds 7 and 13 must produce different models"
    );

    let opts = ServeOptions {
        watch_dir: Some(watch.clone()),
        poll_ms: 20,
        batch_window_ms: 5,
        ..Default::default()
    };
    let server = Server::start(sm_a.clone(), "A", opts).unwrap();
    let mut c = ServeClient::connect(server.local_addr()).unwrap();

    // save_compressed writes via atomic rename, so the watcher never
    // sees a half-written candidate.
    st_b.save_compressed(watch.join("b.thnck"), &sm_b).unwrap();

    // Keep requests in flight across the swap: every answer must be
    // Ok and bitwise from *some* generation — never torn, never lost.
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut swapped = false;
    let mut tag = 0usize;
    while Instant::now() < deadline {
        let input = probe_input(tag);
        tag += 1;
        let r = c.infer(&input, 0).unwrap();
        assert_eq!(r.status, Status::Ok, "no request may drop during reload: {}", r.reason);
        let bits: Vec<u32> = r.output.iter().map(|v| v.to_bits()).collect();
        let from_a =
            bits == oracle(&sm_a, &input).iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let from_b =
            bits == oracle(&sm_b, &input).iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert!(from_a || from_b, "answer came from neither generation");
        if server.snapshot().reloads_ok >= 1 {
            swapped = true;
            break;
        }
    }
    assert!(swapped, "watcher never swapped the valid candidate");

    let snap = server.snapshot();
    assert_eq!(snap.model_version, 2);
    assert_eq!(snap.reloads_rejected, 0);
    let r = c.infer(&probe, 0).unwrap();
    assert_bitwise(&r, &oracle(&sm_b, &probe), "post-swap request must use model B");

    let _ = std::fs::remove_dir_all(&watch);
}

#[test]
fn accept_fault_drops_one_connection_not_the_daemon() {
    let _g = lock_tests();
    faults::clear();
    faults::install(faults::parse_schedule("serve.accept:1=err").unwrap());
    let (_st, sm) = pruned(7);
    let server = Server::start(sm.clone(), "accept-test", Default::default()).unwrap();
    let addr = server.local_addr();

    // First accepted connection is dropped by the injected fault — the
    // client sees an IO error, not a protocol response.
    let mut c1 = ServeClient::connect(addr).unwrap();
    assert!(
        c1.infer(&probe_input(1), 0).is_err(),
        "dropped connection must surface as a client IO error"
    );

    // The daemon keeps accepting.
    let mut c2 = ServeClient::connect(addr).unwrap();
    let input = probe_input(2);
    let r = c2.infer(&input, 0).unwrap();
    assert_bitwise(&r, &oracle(&sm, &input), "connection after accept fault");
    assert_eq!(server.snapshot().accept_faults, 1);
    faults::clear();
}

#[test]
fn transient_reload_errors_are_absorbed_by_retry() {
    let _g = lock_tests();
    faults::clear();
    // Two transient errors at the reload read: within the default
    // RetryPolicy budget (3 extra attempts), so the reload succeeds.
    faults::install(
        faults::parse_schedule("serve.reload:1=err;serve.reload:2=err").unwrap(),
    );
    let watch = temp_dir("retry-watch");

    let (_st_a, sm_a) = pruned(7);
    let (st_b, sm_b) = pruned(13);
    let opts = ServeOptions {
        watch_dir: Some(watch.clone()),
        poll_ms: 20,
        batch_window_ms: 5,
        ..Default::default()
    };
    let server = Server::start(sm_a, "A", opts).unwrap();
    st_b.save_compressed(watch.join("b.thnck"), &sm_b).unwrap();

    assert!(
        wait_until(Duration::from_secs(10), || server.snapshot().reloads_ok >= 1),
        "reload must succeed after retries"
    );
    assert!(faults::stats().retries >= 2, "with_retry must have absorbed both errors");
    assert_eq!(server.snapshot().reloads_rejected, 0);

    let mut c = ServeClient::connect(server.local_addr()).unwrap();
    let input = probe_input(3);
    let r = c.infer(&input, 0).unwrap();
    assert_bitwise(&r, &oracle(&sm_b, &input), "request after retried reload");

    faults::clear();
    let _ = std::fs::remove_dir_all(&watch);
}

#[test]
fn serve_daemon_cli_smoke() {
    let _g = lock_tests();
    faults::clear();
    let dir = temp_dir("cli");
    let (st, sm) = pruned(7);
    let ckpt = dir.join("micro-compressed.thnck");
    st.save_compressed(&ckpt, &sm).unwrap();

    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_thanos"))
        .args(["serve", ckpt.to_str().unwrap(), "--serve_addr=127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();

    // The daemon prints "serving <ckpt> (8->8) on <addr>" once bound.
    let mut reader = std::io::BufReader::new(child.stdout.take().unwrap());
    let mut line = String::new();
    std::io::BufRead::read_line(&mut reader, &mut line).unwrap();
    assert!(line.starts_with("serving "), "unexpected banner: {line:?}");
    let addr = line.rsplit(" on ").next().unwrap().trim().to_string();

    let mut c = ServeClient::connect(addr.as_str()).unwrap();
    let input = probe_input(4);
    let r = c.infer(&input, 0).unwrap();
    assert_bitwise(&r, &oracle(&sm, &input), "request against the CLI daemon");

    child.kill().unwrap();
    child.wait().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
