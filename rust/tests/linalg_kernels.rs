//! Property + determinism tests for the packed register-tiled linalg
//! core (DESIGN.md §Perf-L3): the packed kernels vs the seed (naive)
//! references across non-tile-multiple shapes, ±0.0 inputs, and the
//! serial==parallel bit-identity contract for every rewired kernel.

use thanos::engine;
use thanos::linalg::chol::{
    cholesky, cholesky_in_place, cholesky_naive_in_place, damp_hessian, lower_tri_inverse,
    lower_tri_inverse_naive, upper_tri_solve_many, upper_tri_solve_many_naive,
};
use thanos::linalg::gemm::{matmul, matmul_f64, matmul_naive, recon_loss, xxt_f64, xxt_f64_naive};
use thanos::linalg::kernel::{kf32, kf64, View};
use thanos::linalg::{Mat, MatF64};
use thanos::rng::Rng;

fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut r = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| r.normal_f32(0.0, 1.0))
}

fn random_spd(n: usize, seed: u64) -> MatF64 {
    let x = rand_mat(n, n + 5, seed);
    let mut h = xxt_f64(&x);
    damp_hessian(&mut h, 0.01);
    h
}

fn bits_f32(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

fn bits_f64(m: &MatF64) -> Vec<u64> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// packed kernel vs naive reference across awkward shapes
// ---------------------------------------------------------------------------

#[test]
fn packed_gemm_core_matches_naive_at_awkward_shapes() {
    // exercise the packed core directly (the public matmul routes tiny
    // shapes to the seed path): 1x1, row/col vectors, primes, k = 0
    for (case, &(m, k, n)) in [
        (1usize, 1usize, 1usize),
        (1, 17, 29),
        (29, 17, 1),
        (7, 11, 13),
        (97, 89, 101),
        (5, 0, 9),
        (33, 64, 47),
    ]
    .iter()
    .enumerate()
    {
        let a = rand_mat(m, k, 100 + case as u64);
        let b = rand_mat(k, n, 200 + case as u64);
        let mut c = Mat::zeros(m, n);
        let bp = kf32::pack_b(View::row_major(&b.data, n), k, n);
        kf32::gemm_banded(&mut c.data, n, View::row_major(&a.data, k), 0, m, &bp, false);
        let want = matmul_naive(&a, &b);
        let scale = want.data.iter().fold(1.0f32, |s, &v| s.max(v.abs()));
        assert!(
            c.max_abs_diff(&want) <= 1e-4 * scale,
            "{m}x{k}x{n}: diff {}",
            c.max_abs_diff(&want)
        );
    }
}

#[test]
fn public_matmul_matches_naive_across_density_mix() {
    // rows split between the packed and zero-skip paths; shape above
    // the packed-path flop threshold
    let mut a = rand_mat(64, 80, 7);
    for i in 10..30 {
        for (j, v) in a.row_mut(i).iter_mut().enumerate() {
            if j % 12 != 0 {
                *v = 0.0;
            }
        }
    }
    let b = rand_mat(80, 64, 8);
    let got = matmul(&a, &b);
    let want = matmul_naive(&a, &b);
    let scale = want.data.iter().fold(1.0f32, |s, &v| s.max(v.abs()));
    assert!(got.max_abs_diff(&want) <= 1e-4 * scale);
}

#[test]
fn signed_zero_inputs_are_handled() {
    // ±0.0 rows: zero-skip treats -0.0 as zero; the packed kernel
    // multiplies through. Both must produce exact zeros for a ±0 row.
    // Shape above the packed threshold so the tiled path runs.
    let mut a = rand_mat(64, 72, 9);
    for (j, v) in a.row_mut(3).iter_mut().enumerate() {
        *v = if j % 2 == 0 { 0.0 } else { -0.0 };
    }
    let b = rand_mat(72, 64, 10);
    let got = matmul(&a, &b);
    let want = matmul_naive(&a, &b);
    for j in 0..64 {
        assert_eq!(got.at(3, j), 0.0, "±0 row must stay exactly zero");
    }
    let scale = want.data.iter().fold(1.0f32, |s, &v| s.max(v.abs()));
    assert!(got.max_abs_diff(&want) <= 1e-4 * scale);
}

#[test]
fn packed_f64_gemm_matches_direct() {
    let mut r = Rng::new(11);
    let a = MatF64::from_fn(37, 41, |_, _| r.normal());
    let b = MatF64::from_fn(41, 43, |_, _| r.normal());
    let c = matmul_f64(&a, &b);
    for i in [0usize, 13, 36] {
        for j in [0usize, 21, 42] {
            let direct: f64 = (0..41).map(|p| a.at(i, p) * b.at(p, j)).sum();
            assert!((c.at(i, j) - direct).abs() <= 1e-10 * direct.abs().max(1.0));
        }
    }
}

#[test]
fn packed_syrk_matches_naive_and_is_exactly_symmetric() {
    let x = rand_mat(73, 59, 12); // odd, above the packed threshold
    let h = xxt_f64(&x);
    let hn = xxt_f64_naive(&x);
    let scale = hn.data.iter().fold(1.0f64, |s, &v| s.max(v.abs()));
    assert!(h.max_abs_diff(&hn) <= 1e-12 * scale.max(1.0) * 1e3);
    for i in 0..73 {
        for j in 0..i {
            assert_eq!(
                h.at(i, j).to_bits(),
                h.at(j, i).to_bits(),
                "symmetry must be bitwise ({i},{j})"
            );
        }
    }
}

#[test]
fn blocked_cholesky_matches_naive_reference_large() {
    let a = random_spd(210, 13);
    let mut blocked = a.clone();
    cholesky_in_place(&mut blocked).unwrap();
    let mut naive = a.clone();
    cholesky_naive_in_place(&mut naive).unwrap();
    let scale = naive.data.iter().fold(1.0f64, |s, &v| s.max(v.abs()));
    assert!(blocked.max_abs_diff(&naive) <= 1e-9 * scale.max(1.0));
}

#[test]
fn blocked_trsm_and_tri_inverse_match_naive() {
    let a = random_spd(160, 14);
    let l = cholesky(&a).unwrap();
    let li_blocked = lower_tri_inverse(&l);
    let li_naive = lower_tri_inverse_naive(&l);
    assert!(li_blocked.max_abs_diff(&li_naive) <= 1e-9);

    let mut r = Rng::new(15);
    let off = 1.0 / 160.0;
    let u = MatF64::from_fn(160, 160, |i, j| {
        if i > j {
            0.0
        } else if i == j {
            2.0
        } else {
            off * r.normal()
        }
    });
    let rhs = MatF64::from_fn(160, 70, |_, _| r.normal());
    let xs = upper_tri_solve_many(&u, &rhs);
    let xn = upper_tri_solve_many_naive(&u, &rhs);
    assert!(xs.max_abs_diff(&xn) <= 1e-9);
    // residual: U·X == RHS
    let prod = matmul_f64(&u, &xs);
    assert!(prod.max_abs_diff(&rhs) <= 1e-9);
}

// ---------------------------------------------------------------------------
// serial == parallel bit-identity for every rewired kernel
// ---------------------------------------------------------------------------

#[test]
fn gemm_serial_parallel_bit_identical() {
    // shape above every packed threshold so the tiled path runs
    let a = rand_mat(64, 72, 16);
    let b = rand_mat(72, 64, 17);
    let par = matmul(&a, &b);
    let ser = engine::with_serial(|| matmul(&a, &b));
    assert_eq!(bits_f32(&par), bits_f32(&ser));
}

#[test]
fn gemm_f64_serial_parallel_bit_identical() {
    let mut r = Rng::new(18);
    let a = MatF64::from_fn(64, 72, |_, _| r.normal());
    let b = MatF64::from_fn(72, 64, |_, _| r.normal());
    let par = matmul_f64(&a, &b);
    let ser = engine::with_serial(|| matmul_f64(&a, &b));
    assert_eq!(bits_f64(&par), bits_f64(&ser));
}

#[test]
fn syrk_serial_parallel_bit_identical() {
    let x = rand_mat(96, 80, 19);
    let par = xxt_f64(&x);
    let ser = engine::with_serial(|| xxt_f64(&x));
    assert_eq!(bits_f64(&par), bits_f64(&ser));
}

#[test]
fn blocked_cholesky_serial_parallel_bit_identical() {
    // n > PAR_MIN so the banded TRSM + trailing update actually fan out
    let a = random_spd(300, 20);
    let mut par = a.clone();
    cholesky_in_place(&mut par).unwrap();
    let ser = engine::with_serial(|| {
        let mut m = a.clone();
        cholesky_in_place(&mut m).unwrap();
        m
    });
    assert_eq!(bits_f64(&par), bits_f64(&ser));
}

#[test]
fn blocked_trsm_serial_parallel_bit_identical() {
    let mut r = Rng::new(21);
    let off = 1.0 / 200.0;
    let u = MatF64::from_fn(200, 200, |i, j| {
        if i > j {
            0.0
        } else if i == j {
            2.0
        } else {
            off * r.normal()
        }
    });
    let rhs = MatF64::from_fn(200, 96, |_, _| r.normal());
    let par = upper_tri_solve_many(&u, &rhs);
    let ser = engine::with_serial(|| upper_tri_solve_many(&u, &rhs));
    assert_eq!(bits_f64(&par), bits_f64(&ser));
}

#[test]
fn blocked_tri_inverse_serial_parallel_bit_identical() {
    let a = random_spd(180, 22);
    let l = cholesky(&a).unwrap();
    let par = lower_tri_inverse(&l);
    let ser = engine::with_serial(|| lower_tri_inverse(&l));
    assert_eq!(bits_f64(&par), bits_f64(&ser));
}

#[test]
fn recon_loss_serial_parallel_bit_identical_integration() {
    let w = rand_mat(50, 70, 23);
    let mut w_hat = w.clone();
    for v in w_hat.data.iter_mut().step_by(2) {
        *v = 0.0;
    }
    let x = rand_mat(70, 60, 24);
    let par = recon_loss(&w_hat, &w, &x);
    let ser = engine::with_serial(|| recon_loss(&w_hat, &w, &x));
    assert_eq!(par.to_bits(), ser.to_bits());
}

// ---------------------------------------------------------------------------
// the f64 packed core at awkward shapes (used by chol/TRSM internally)
// ---------------------------------------------------------------------------

#[test]
fn packed_f64_core_matches_direct_at_awkward_shapes() {
    for (case, &(m, k, n)) in
        [(1usize, 1usize, 1usize), (5, 7, 3), (13, 29, 11), (40, 3, 50)].iter().enumerate()
    {
        let mut r = Rng::new(300 + case as u64);
        let a: Vec<f64> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| r.normal()).collect();
        let mut c = vec![0.0f64; m * n];
        let bp = kf64::pack_b(View::row_major(&b, n), k, n);
        kf64::gemm_banded(&mut c, n, View::row_major(&a, k), 0, m, &bp, false);
        for i in 0..m {
            for j in 0..n {
                let direct: f64 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                assert!(
                    (c[i * n + j] - direct).abs() <= 1e-12 * direct.abs().max(1.0),
                    "{m}x{k}x{n} at ({i},{j})"
                );
            }
        }
    }
}
