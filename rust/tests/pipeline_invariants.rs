//! Pipeline-level invariants over the coordinator + model state +
//! checkpoint IO (needs artifacts; skipped gracefully otherwise).

use thanos::coordinator::{Backend, Coordinator, PruneSpec};
use thanos::data::{Corpus, CorpusConfig};
use thanos::eval;
use thanos::model::ModelState;
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Runtime::load("artifacts").expect("loading runtime"))
}

fn small_corpus(seq_len: usize) -> Corpus {
    Corpus::build(&CorpusConfig {
        seq_len,
        train_seqs: 32,
        calib_seqs: 16,
        eval_seqs: 8,
        ..Default::default()
    })
}

#[test]
fn pipeline_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let Ok(mm) = rt.model("tiny") else { return };
    let corpus = small_corpus(mm.config.seq_len);
    let base = ModelState::init(mm, 31);
    let spec = PruneSpec {
        method: Method::Thanos,
        pattern: Pattern::Unstructured { p: 0.5 },
        opts: PruneOpts::default(),
        backend: Backend::Rust,
    };
    let run = || {
        let mut st = base.clone();
        Coordinator::new(&rt)
            .prune_model(&mut st, &corpus.calib, &spec)
            .unwrap();
        st.flat
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed + same calib must give identical pruned weights");
}

#[test]
fn rust_and_aot_backends_agree_on_quality() {
    // identical mask selection is not guaranteed (f32 vs f64 stats),
    // but end-model perplexity must be close
    let Some(rt) = runtime() else { return };
    let Ok(mm) = rt.model("tiny") else { return };
    let corpus = small_corpus(mm.config.seq_len);
    let base = ModelState::init(mm, 33);
    let mut ppls = Vec::new();
    for backend in [Backend::Rust, Backend::Aot] {
        let mut st = base.clone();
        let spec = PruneSpec {
            method: Method::Wanda,
            pattern: Pattern::Unstructured { p: 0.5 },
            opts: PruneOpts::default(),
            backend,
        };
        Coordinator::new(&rt)
            .prune_model(&mut st, &corpus.calib, &spec)
            .unwrap();
        ppls.push(eval::perplexity(&rt, &st, &corpus.eval).unwrap());
    }
    let rel = (ppls[0] - ppls[1]).abs() / ppls[0];
    assert!(rel < 0.01, "backend ppl mismatch: {ppls:?}");
}

#[test]
fn pruned_checkpoint_roundtrips_through_disk() {
    let Some(rt) = runtime() else { return };
    let Ok(mm) = rt.model("tiny") else { return };
    let corpus = small_corpus(mm.config.seq_len);
    let mut st = ModelState::init(mm, 35);
    let spec = PruneSpec {
        method: Method::Thanos,
        pattern: Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 },
        opts: PruneOpts::default(),
        backend: Backend::Rust,
    };
    Coordinator::new(&rt)
        .prune_model(&mut st, &corpus.calib, &spec)
        .unwrap();
    let dir = std::env::temp_dir().join("thanos_pipeline_test");
    let path = dir.join("pruned.thnck");
    st.save(&path).unwrap();
    let back = ModelState::load(&path).unwrap();
    assert_eq!(back.flat, st.flat);
    // sparsity + eval identical after reload
    assert_eq!(back.prunable_sparsity(), st.prunable_sparsity());
    let p1 = eval::perplexity(&rt, &st, &corpus.eval).unwrap();
    let p2 = eval::perplexity(&rt, &back, &corpus.eval).unwrap();
    assert_eq!(p1, p2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn structured_pruning_shrinks_effective_columns_consistently() {
    // every layer pruned by structured Thanos removes the same COUNT of
    // columns (⌈p·b/(1−α)⌉ for its own b) across the whole model
    let Some(rt) = runtime() else { return };
    let Ok(mm) = rt.model("tiny") else { return };
    let corpus = small_corpus(mm.config.seq_len);
    let mut st = ModelState::init(mm, 37);
    let (p, alpha) = (0.25, 0.1);
    let spec = PruneSpec {
        method: Method::Thanos,
        pattern: Pattern::Structured { p, alpha },
        opts: PruneOpts::default(),
        backend: Backend::Rust,
    };
    Coordinator::new(&rt)
        .prune_model(&mut st, &corpus.calib, &spec)
        .unwrap();
    for l in 0..st.config.n_layers {
        for name in st.prunable_layers(l) {
            let w = st.get_mat(&name).unwrap();
            let keep_rows = (alpha * w.rows as f64).ceil() as usize;
            let want_cols = ((p * w.cols as f64) / (1.0 - alpha)).ceil() as usize;
            // a column counts as removed if zero in all non-outlier rows;
            // outlier rows are the `keep_rows` with unchanged weights
            let mut zero_cols = 0;
            for j in 0..w.cols {
                let zeros = (0..w.rows).filter(|&i| w.at(i, j) == 0.0).count();
                if zeros >= w.rows - keep_rows {
                    zero_cols += 1;
                }
            }
            assert_eq!(zero_cols, want_cols, "{name}");
        }
    }
}

#[test]
fn eval_perplexity_stable_across_batch_boundaries() {
    // 8 eval seqs vs the same 8 + padding path must agree exactly
    let Some(rt) = runtime() else { return };
    let Ok(mm) = rt.model("tiny") else { return };
    let corpus = small_corpus(mm.config.seq_len);
    let st = ModelState::init(mm, 39);
    let full = eval::perplexity(&rt, &st, &corpus.eval).unwrap();
    // a split with a partial final batch (5 = 8-batch + pad path)
    let partial = thanos::data::Sequences {
        seq_len: corpus.eval.seq_len,
        tokens: corpus.eval.tokens[..5 * corpus.eval.seq_len].to_vec(),
    };
    let p5 = eval::perplexity(&rt, &st, &partial).unwrap();
    assert!(p5.is_finite());
    // and the first batch alone matches the mean over itself
    let first8 = thanos::data::Sequences {
        seq_len: corpus.eval.seq_len,
        tokens: corpus.eval.tokens[..8 * corpus.eval.seq_len].to_vec(),
    };
    let p8 = eval::perplexity(&rt, &st, &first8).unwrap();
    assert!((p8.ln() - full.ln()).abs() < 0.2, "{p8} vs {full}");
}
