//! Model-checked regression test for `PruneEngine`'s termination
//! protocol (see `thanos::engine::model` for the model itself).
//!
//! The pre-fix `Drop` stored the `shutdown` flag without holding the
//! queue mutex; a worker that had already checked the flag and found
//! the queue empty — but had not yet parked on `work_cv` — consumed no
//! notify and slept forever, hanging the `join`. The checker exhausts
//! every interleaving of both protocol variants, so this test fails if
//! either the fix regresses (locked variant deadlocks) or the model
//! rots (buggy variant stops witnessing the race it exists to pin).

use thanos::engine::model::{explore, Config, Outcome};

#[test]
fn shipped_drop_protocol_is_deadlock_free_across_pool_shapes() {
    for (workers, tasks) in [(1, 1), (1, 3), (2, 2), (2, 4), (3, 2)] {
        let out = explore(&Config { workers, tasks, locked_shutdown: true });
        match out {
            Outcome::Clean { states, terminals } => {
                assert!(states > 0 && terminals > 0, "{workers}w/{tasks}t: empty exploration");
            }
            other => panic!("{workers} workers / {tasks} tasks: {other:?}"),
        }
    }
}

#[test]
fn prefix_drop_protocol_deadlocks_and_the_trace_shows_the_lost_wakeup() {
    let out = explore(&Config { workers: 2, tasks: 2, locked_shutdown: false });
    let (states, trace) = match out {
        Outcome::Stuck { states, trace } => (states, trace),
        other => panic!("the unlocked shutdown store should deadlock, got {other:?}"),
    };
    assert!(states > 0);
    let joined = trace.join("\n");
    // the witness: the store lands while a worker is between its
    // shutdown check and parking, so the final notify precedes the park
    let store = trace.iter().position(|s| s.contains("no lock"));
    // the fatal park is the last one — nothing can wake it afterwards
    let park = trace.iter().rposition(|s| s.contains("parks on work_cv"));
    assert!(store.is_some() && park.is_some(), "{joined}");
    assert!(store < park, "store should precede the fatal park:\n{joined}");
    assert!(joined.contains("STUCK"), "{joined}");
}

#[test]
fn every_terminal_state_executes_each_task_exactly_once() {
    // BadTerminal (a terminal state with unclaimed tasks or a nonzero
    // completion latch) must be unreachable under the shipped protocol.
    for tasks in 1..=4 {
        let out = explore(&Config { workers: 2, tasks, locked_shutdown: true });
        assert!(
            matches!(out, Outcome::Clean { .. }),
            "tasks={tasks}: {out:?}"
        );
    }
}
