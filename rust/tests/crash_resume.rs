//! Chaos harness for the journaled pruning pipeline (DESIGN.md
//! §Robustness): kill the run at every fault site — in-process panics,
//! injected transient IO errors, torn writes, and a real
//! `process::exit` in a subprocess — then `--resume` and assert the
//! final weights and the progress-checkpoint **bytes** are identical
//! to an uninterrupted run, across patterns and serial/parallel
//! execution.
//!
//! The walk is driven through a synthetic [`BlockPipeline`] so no AOT
//! artifacts are needed: activations evolve from a digest of each
//! (pruned) block's weights, so later blocks genuinely depend on
//! earlier pruning decisions — a resume that restored the wrong bytes
//! would diverge.
//!
//! Fault schedules are process-global, so every test serializes on one
//! lock. `THANOS_CHAOS_ARTIFACTS=<dir>` exports a journal + progress
//! checkpoint for CI artifact upload.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::Result;
use thanos::config::ModelConfig;
use thanos::coordinator::{
    progress_ckpt_path, run_pruning, Backend, BlockPipeline, PruneReport, PruneSpec, RobustOpts,
};
use thanos::linalg::Mat;
use thanos::model::ModelState;
use thanos::pruning::{CalibStats, Method, Pattern, PruneOpts};
use thanos::robust::faults;
use thanos::robust::{crc64_f32s, RetryPolicy};
use thanos::runtime::{ModelManifest, ParamEntry};

/// Fault schedules are process-global state: every test takes this.
static LOCK: Mutex<()> = Mutex::new(());

const SEED: u64 = 0xC4A5;
const CHILD_ENV: &str = "THANOS_CHAOS_CHILD";

// ------------------------------------------------------------------
// synthetic model + pipeline

/// Micro 3-block manifest mirroring the python param_specs layout.
fn micro_manifest() -> ModelManifest {
    let cfg = ModelConfig {
        name: "micro3".into(),
        vocab: 16,
        d_model: 8,
        n_layers: 3,
        n_heads: 2,
        d_ff: 16,
        seq_len: 4,
    };
    let mut layout = Vec::new();
    let mut off = 0usize;
    let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
        let numel: usize = shape.iter().product();
        layout.push(ParamEntry { name: name.into(), offset: *off, shape });
        *off += numel;
    };
    push(&mut layout, "emb", vec![16, 8], &mut off);
    push(&mut layout, "pos", vec![4, 8], &mut off);
    let mut block_flat = 0;
    for l in 0..cfg.n_layers {
        let before = off;
        push(&mut layout, &format!("blocks.{l}.ln1"), vec![8], &mut off);
        for w in ["wq", "wk", "wv", "wo"] {
            push(&mut layout, &format!("blocks.{l}.{w}"), vec![8, 8], &mut off);
        }
        push(&mut layout, &format!("blocks.{l}.ln2"), vec![8], &mut off);
        push(&mut layout, &format!("blocks.{l}.w1"), vec![16, 8], &mut off);
        push(&mut layout, &format!("blocks.{l}.w2"), vec![8, 16], &mut off);
        block_flat = off - before;
    }
    push(&mut layout, "ln_f", vec![8], &mut off);
    ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
}

/// Deterministic calibration statistics derived from the activation
/// vector: distinct per site (`salt`), diagonally seeded so the Hessian
/// is comfortably positive definite for the solver-based methods.
fn synth_stats(x: &[f32], b: usize, a: usize, salt: usize) -> CalibStats {
    let mut data = vec![0.0f32; b * a];
    for i in 0..b {
        for j in 0..a {
            let v = x[(i * 31 + j * 7 + salt) % x.len()];
            let texture = ((i * 13 + j * 5 + salt) % 17) as f32 * 0.07;
            let diag = if j % b == i { 1.0 } else { 0.0 };
            data[i * a + j] = v + texture + diag;
        }
    }
    let mut s = CalibStats::new(b);
    s.accumulate(&Mat::from_vec(b, a, data));
    s
}

/// Artifact-free [`BlockPipeline`]: `begin` reads only unpruned params
/// (the embedding, like the real embed pass), `reforward` folds a
/// digest of the block's **current** weights into the activations — so
/// `begin` + `reforward(0..k)` replayed over a restored state
/// reproduces the activations of an uninterrupted run bit-for-bit, and
/// any restore mismatch propagates into every later block's statistics.
struct SynthPipe {
    n_blocks: usize,
    d: usize,
    d_ff: usize,
    a: usize,
    x: Vec<f32>,
}

impl SynthPipe {
    fn new(cfg: &ModelConfig) -> Self {
        SynthPipe { n_blocks: cfg.n_layers, d: cfg.d_model, d_ff: cfg.d_ff, a: 32, x: Vec::new() }
    }
}

impl BlockPipeline for SynthPipe {
    fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    fn begin(&mut self, state: &ModelState) -> Result<()> {
        let emb = state.get_mat("emb")?;
        self.x = (0..64).map(|i| emb.data[i % emb.data.len()]).collect();
        Ok(())
    }

    fn capture(&mut self, state: &ModelState, l: usize) -> Result<Vec<CalibStats>> {
        state.block_slice(l)?; // same existence check as the real pipeline
        Ok(vec![
            synth_stats(&self.x, self.d, self.a, 1),
            synth_stats(&self.x, self.d, self.a, 2),
            synth_stats(&self.x, self.d, self.a, 3),
            synth_stats(&self.x, self.d_ff, self.a, 4),
        ])
    }

    fn reforward(&mut self, state: &ModelState, l: usize) -> Result<()> {
        let digest = crc64_f32s(state.block_slice(l)?);
        for (i, v) in self.x.iter_mut().enumerate() {
            let k = ((digest >> (8 * (i % 8))) & 0xFF) as f32 / 255.0;
            *v = 0.5 * *v + 0.25 * k + 0.01;
        }
        Ok(())
    }

    fn take_stage_secs(&mut self) -> (f64, f64, f64) {
        (0.0, 0.0, 0.0)
    }
}

// ------------------------------------------------------------------
// harness helpers

fn spec(pattern: Pattern) -> PruneSpec {
    PruneSpec {
        method: Method::Thanos,
        pattern,
        opts: PruneOpts { block_size: 4, ..Default::default() },
        backend: Backend::Rust,
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("thanos-chaos-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Uninterrupted journaled run: final weight bits + the bytes of the
/// progress checkpoint it leaves behind.
fn reference(mm: &ModelManifest, sp: &PruneSpec, jpath: &Path) -> (Vec<u32>, Vec<u8>) {
    faults::clear();
    let mut state = ModelState::init(mm, SEED);
    let mut pipe = SynthPipe::new(&mm.config);
    let robust =
        RobustOpts { journal: Some(jpath.to_path_buf()), resume: false, ..Default::default() };
    run_pruning(&mut state, &mut pipe, sp, &robust).expect("uninterrupted reference run");
    let ckpt = std::fs::read(progress_ckpt_path(jpath)).unwrap();
    (bits(&state.flat), ckpt)
}

/// Install `schedule`, run until it kills the walk (panic or error),
/// clear faults, resume from the journal, and return the resumed final
/// bits + checkpoint bytes + resume report.
fn kill_then_resume(
    mm: &ModelManifest,
    sp: &PruneSpec,
    jpath: &Path,
    schedule: &str,
) -> (Vec<u32>, Vec<u8>, PruneReport) {
    let _ = std::fs::remove_file(jpath);
    let _ = std::fs::remove_file(progress_ckpt_path(jpath));
    faults::install(faults::parse_schedule(schedule).unwrap());
    let robust =
        RobustOpts { journal: Some(jpath.to_path_buf()), resume: false, ..Default::default() };
    let crashed = catch_unwind(AssertUnwindSafe(|| {
        let mut state = ModelState::init(mm, SEED);
        let mut pipe = SynthPipe::new(&mm.config);
        run_pruning(&mut state, &mut pipe, sp, &robust).map(|_| ())
    }));
    assert!(
        !matches!(crashed, Ok(Ok(()))),
        "schedule '{schedule}' did not interrupt the run"
    );
    faults::clear();
    let mut state = ModelState::init(mm, SEED);
    let mut pipe = SynthPipe::new(&mm.config);
    let robust =
        RobustOpts { journal: Some(jpath.to_path_buf()), resume: true, ..Default::default() };
    let report = run_pruning(&mut state, &mut pipe, sp, &robust)
        .unwrap_or_else(|e| panic!("resume after '{schedule}' failed: {e:#}"));
    let ckpt = std::fs::read(progress_ckpt_path(jpath)).unwrap();
    (bits(&state.flat), ckpt, report)
}

// ------------------------------------------------------------------
// the kill-at-site matrix

#[test]
fn kill_at_every_fault_site_then_resume_is_bitwise_identical() {
    let _g = LOCK.lock().unwrap();
    // under THANOS_CHAOS_ARTIFACTS (CI), also record a Chrome trace of
    // the whole matrix so the robust.* spans land in the artifacts
    let artifacts = std::env::var("THANOS_CHAOS_ARTIFACTS").ok();
    if artifacts.is_some() {
        thanos::trace::set_enabled(true);
    }
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("matrix");
    let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join("ref.journal"));
    let jpath = dir.join("kill.journal");

    let mut schedules: Vec<String> = Vec::new();
    for site in faults::SITES {
        // first hit (before any block commits) and a later hit (after
        // at least one block record exists → a real mid-run resume)
        schedules.push(format!("{site}:1=panic"));
        schedules.push(format!("{site}:2=panic"));
    }
    // layer-task kills: contained by prune_many, surface as errors
    schedules.push("prune.layer.0:1=panic".into());
    schedules.push("prune.layer.4:2=panic".into());

    let mut total_resumed = 0u64;
    for schedule in &schedules {
        let (got_bits, got_ckpt, report) = kill_then_resume(&mm, &sp, &jpath, schedule);
        assert_eq!(got_bits, ref_bits, "final weights diverge after '{schedule}'");
        assert_eq!(got_ckpt, ref_ckpt, "checkpoint bytes diverge after '{schedule}'");
        total_resumed += report.resumed_layers;
    }
    assert!(
        total_resumed > 0,
        "no schedule exercised a true resume (all restarted from scratch)"
    );

    if let Some(out) = artifacts {
        let out = PathBuf::from(out);
        std::fs::create_dir_all(&out).unwrap();
        std::fs::copy(&jpath, out.join("chaos.journal")).unwrap();
        std::fs::copy(progress_ckpt_path(&jpath), out.join("chaos.journal.ckpt")).unwrap();
        thanos::trace::export_to(&out.join("chaos-trace.json")).unwrap();
        thanos::trace::set_enabled(false);
    }
}

#[test]
fn resume_matrix_across_patterns_and_threading() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let dir = tmpdir("patterns");
    let patterns = [
        Pattern::Unstructured { p: 0.5 },
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 },
        Pattern::Structured { p: 0.5, alpha: 0.1 },
    ];
    for (pi, pattern) in patterns.into_iter().enumerate() {
        let sp = spec(pattern);
        let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join(format!("ref{pi}.journal")));
        for serial in [false, true] {
            for schedule in
                ["atomic.rename:2=panic", "journal.sync:3=panic", "prune.layer.0:2=panic"]
            {
                let jpath = dir.join(format!("p{pi}-s{serial}.journal"));
                let run = || kill_then_resume(&mm, &sp, &jpath, schedule);
                let (got_bits, got_ckpt, _) =
                    if serial { thanos::engine::with_serial(run) } else { run() };
                assert_eq!(
                    got_bits, ref_bits,
                    "{pattern:?} serial={serial} '{schedule}': weights diverge"
                );
                assert_eq!(
                    got_ckpt, ref_ckpt,
                    "{pattern:?} serial={serial} '{schedule}': checkpoint bytes diverge"
                );
            }
        }
    }
}

// ------------------------------------------------------------------
// a true process kill (skips every Drop), via subprocess re-exec

/// Runs only in the spawned child: prune with an `exit` fault armed, so
/// the process dies mid-run with no unwinding and no `Drop` cleanup.
#[test]
fn chaos_child_worker() {
    let Ok(jpath) = std::env::var(CHILD_ENV) else { return };
    let schedule = std::env::var("THANOS_CHAOS_CHILD_FAULTS").unwrap();
    faults::install(faults::parse_schedule(&schedule).unwrap());
    let mm = micro_manifest();
    let mut state = ModelState::init(&mm, SEED);
    let mut pipe = SynthPipe::new(&mm.config);
    let robust =
        RobustOpts { journal: Some(PathBuf::from(jpath)), resume: false, ..Default::default() };
    let _ = run_pruning(&mut state, &mut pipe, &spec(Pattern::Unstructured { p: 0.5 }), &robust);
    // the armed exit should have killed the process before this line
    std::process::exit(0);
}

#[test]
fn a_real_process_kill_resumes_bitwise_identical() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("kill");
    let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join("ref.journal"));
    let jpath = dir.join("child.journal");
    let _ = std::fs::remove_file(&jpath);
    let _ = std::fs::remove_file(progress_ckpt_path(&jpath));

    let exe = std::env::current_exe().unwrap();
    let status = std::process::Command::new(&exe)
        .args(["chaos_child_worker", "--exact", "--nocapture", "--test-threads=1"])
        .env(CHILD_ENV, &jpath)
        .env("THANOS_CHAOS_CHILD_FAULTS", "atomic.rename:2=exit(41)")
        .status()
        .unwrap();
    assert_eq!(status.code(), Some(41), "child should die at the injected exit");

    faults::clear();
    let mut state = ModelState::init(&mm, SEED);
    let mut pipe = SynthPipe::new(&mm.config);
    let robust = RobustOpts { journal: Some(jpath.clone()), resume: true, ..Default::default() };
    let report = run_pruning(&mut state, &mut pipe, &sp, &robust).unwrap();
    assert!(report.resumed_layers > 0, "the kill landed after a block committed");
    assert_eq!(bits(&state.flat), ref_bits, "weights diverge after a process kill");
    assert_eq!(
        std::fs::read(progress_ckpt_path(&jpath)).unwrap(),
        ref_ckpt,
        "checkpoint bytes diverge after a process kill"
    );
}

// ------------------------------------------------------------------
// journal edge cases

#[test]
fn resume_tolerates_a_torn_journal_tail() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("torn");
    let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join("ref.journal"));
    let jpath = dir.join("torn.journal");
    let _ = std::fs::remove_file(&jpath);
    let _ = std::fs::remove_file(progress_ckpt_path(&jpath));

    // crash at the second block commit, then simulate the torn tail a
    // mid-append power cut leaves behind
    faults::install(faults::parse_schedule("atomic.sync:2=panic").unwrap());
    let robust = RobustOpts { journal: Some(jpath.clone()), resume: false, ..Default::default() };
    let crashed = catch_unwind(AssertUnwindSafe(|| {
        let mut state = ModelState::init(&mm, SEED);
        let mut pipe = SynthPipe::new(&mm.config);
        run_pruning(&mut state, &mut pipe, &sp, &robust).map(|_| ())
    }));
    assert!(crashed.is_err(), "expected the injected panic");
    faults::clear();
    let mut bytes = std::fs::read(&jpath).unwrap();
    bytes.extend_from_slice(&[0x17u8; 9]);
    std::fs::write(&jpath, &bytes).unwrap();

    let mut state = ModelState::init(&mm, SEED);
    let mut pipe = SynthPipe::new(&mm.config);
    let robust = RobustOpts { journal: Some(jpath.clone()), resume: true, ..Default::default() };
    let report = run_pruning(&mut state, &mut pipe, &sp, &robust).unwrap();
    assert_eq!(report.resumed_layers, 6, "block 0 committed before the crash");
    assert_eq!(bits(&state.flat), ref_bits);
    assert_eq!(std::fs::read(progress_ckpt_path(&jpath)).unwrap(), ref_ckpt);
}

#[test]
fn resume_refuses_a_journal_from_a_different_run() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let dir = tmpdir("mismatch");
    let jpath = dir.join("mismatch.journal");
    reference(&mm, &spec(Pattern::Unstructured { p: 0.5 }), &jpath);

    // same journal, different pattern → the run descriptor differs
    let mut state = ModelState::init(&mm, SEED);
    let mut pipe = SynthPipe::new(&mm.config);
    let robust = RobustOpts { journal: Some(jpath.clone()), resume: true, ..Default::default() };
    let sp2 = spec(Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 });
    let err = run_pruning(&mut state, &mut pipe, &sp2, &robust).unwrap_err();
    assert!(format!("{err:#}").contains("different run"), "{err:#}");
}

// ------------------------------------------------------------------
// graceful degradation + retry accounting

#[test]
fn failed_layers_are_contained_survivors_land_and_resume_completes() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("degrade");
    let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join("ref.journal"));
    let jpath = dir.join("degrade.journal");

    let degraded_run = |jp: &Path| -> (ModelState, String) {
        let _ = std::fs::remove_file(jp);
        let _ = std::fs::remove_file(progress_ckpt_path(jp));
        faults::install(
            faults::parse_schedule("prune.layer.1:1=err;prune.layer.3:1=panic").unwrap(),
        );
        let mut state = ModelState::init(&mm, SEED);
        let mut pipe = SynthPipe::new(&mm.config);
        let robust =
            RobustOpts { journal: Some(jp.to_path_buf()), resume: false, ..Default::default() };
        let err = run_pruning(&mut state, &mut pipe, &sp, &robust).unwrap_err();
        faults::clear();
        (state, format!("{err:#}"))
    };

    let (state, msg) = degraded_run(&jpath);
    // one injected error + one contained panic, both named, run failed
    assert!(msg.contains("2 layer(s) failed"), "{msg}");
    assert!(msg.contains("blocks.0.wk"), "{msg}");
    assert!(msg.contains("blocks.0.wo"), "{msg}");
    assert!(msg.contains("journaled"), "{msg}");
    // survivors of the block were still pruned and applied…
    assert!(state.get_mat("blocks.0.wq").unwrap().sparsity() > 0.4);
    // …while the failed layers kept their original weights
    let orig = ModelState::init(&mm, SEED);
    assert_eq!(
        bits(&state.get_mat("blocks.0.wk").unwrap().data),
        bits(&orig.get_mat("blocks.0.wk").unwrap().data),
    );

    // the degraded state is itself deterministic: serial == parallel
    let (state2, _) = thanos::engine::with_serial(|| degraded_run(&dir.join("degrade2.journal")));
    assert_eq!(bits(&state2.flat), bits(&state.flat), "degraded state depends on scheduling");

    // resume re-prunes the failed block from scratch and converges
    let mut state = ModelState::init(&mm, SEED);
    let mut pipe = SynthPipe::new(&mm.config);
    let robust = RobustOpts { journal: Some(jpath.clone()), resume: true, ..Default::default() };
    run_pruning(&mut state, &mut pipe, &sp, &robust).unwrap();
    assert_eq!(bits(&state.flat), ref_bits);
    assert_eq!(std::fs::read(progress_ckpt_path(&jpath)).unwrap(), ref_ckpt);
}

#[test]
fn transient_faults_are_retried_counted_and_leave_no_trace_in_the_output() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("retry");
    let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join("ref.journal"));

    // transient errors on both sync paths + one torn journal append:
    // all three are absorbed by the bounded deterministic retry
    let jpath = dir.join("retry.journal");
    let _ = std::fs::remove_file(&jpath);
    faults::install(
        faults::parse_schedule("journal.sync:1=err;atomic.sync:1=err;journal.append:3=trunc(6)")
            .unwrap(),
    );
    let mut state = ModelState::init(&mm, SEED);
    let mut pipe = SynthPipe::new(&mm.config);
    let robust = RobustOpts { journal: Some(jpath.clone()), resume: false, ..Default::default() };
    let report = run_pruning(&mut state, &mut pipe, &sp, &robust).unwrap();
    faults::clear();
    assert_eq!(report.faults_injected, 3, "all three scheduled faults should fire");
    assert!(report.retries >= 3, "each transient fault costs at least one retry");
    assert!(
        report.summary().contains("injected fault(s)"),
        "robust gauges missing from the summary:\n{}",
        report.summary()
    );
    assert_eq!(bits(&state.flat), ref_bits, "retries must not change the result");
    assert_eq!(std::fs::read(progress_ckpt_path(&jpath)).unwrap(), ref_ckpt);

    // the backoff ladder is part of the determinism contract: pin it
    let p = RetryPolicy::default();
    let ladder: Vec<u64> = (0..5).map(|r| p.backoff_millis(r)).collect();
    assert_eq!(ladder, [1, 4, 16, 50, 50]);
}
