//! Tracer lifecycle tests (DESIGN.md §Observability).
//!
//! The tracer's enable flag, shard registry and output path are
//! PROCESS-GLOBAL, and `cargo test` runs a binary's tests in parallel
//! threads — so every scenario that toggles or drains that state runs
//! inside ONE test function here, in a fixed order, in its own test
//! binary. The pure span/histogram math is unit-tested in
//! `rust/src/trace/` instead.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use thanos::engine::{self, PruneEngine};
use thanos::jsonutil::Json;
use thanos::linalg::gemm::matmul;
use thanos::linalg::Mat;
use thanos::pruning::{prune, CalibStats, Method, Pattern, PruneOpts};
use thanos::rng::Rng;
use thanos::trace;

/// Parse an exported Chrome trace and check well-formedness: every
/// `tid`'s B/E stream is strictly LIFO-balanced with monotone
/// non-decreasing timestamps, and no stream is left open.
fn check_chrome_trace(path: &std::path::Path) -> usize {
    let doc = Json::parse_file(path).expect("trace file parses as JSON");
    let events = doc
        .get("traceEvents")
        .expect("traceEvents key")
        .as_arr()
        .expect("traceEvents is an array");
    let mut stacks: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    let mut n_spans = 0usize;
    for ev in events {
        let ph = ev.get("ph").expect("ph").as_str().expect("ph str").to_string();
        if ph == "M" {
            continue; // thread_name metadata
        }
        let tid = ev.get("tid").expect("tid").as_f64().expect("tid num") as u64;
        let ts = ev.get("ts").expect("ts").as_f64().expect("ts num");
        let name = ev.get("name").expect("name").as_str().expect("name str").to_string();
        let prev = last_ts.insert(tid, ts).unwrap_or(f64::NEG_INFINITY);
        assert!(ts >= prev, "tid {tid}: ts went backwards ({prev} -> {ts})");
        let stack = stacks.entry(tid).or_default();
        match ph.as_str() {
            "B" => stack.push(name),
            "E" => {
                let open = stack.pop().unwrap_or_else(|| {
                    panic!("tid {tid}: E '{name}' with empty stack")
                });
                assert_eq!(open, name, "tid {tid}: spans not LIFO");
                n_spans += 1;
            }
            other => panic!("unexpected phase '{other}'"),
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "tid {tid}: {} span(s) left open", stack.len());
    }
    n_spans
}

/// Synthetic calibrated layer, same shape recipe as the bench harness.
fn layer(c: usize, b: usize, a: usize, seed: u64) -> (Mat, CalibStats) {
    let mut r = Rng::new(seed);
    let w = Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
    let k = (b / 4).max(2);
    let factors = Mat::from_fn(k, a, |_, _| r.normal_f32(0.0, 1.0));
    let loading = Mat::from_fn(b, k, |_, _| r.normal_f32(0.0, 1.0));
    let mut x = matmul(&loading, &factors);
    for v in x.data.iter_mut() {
        *v += r.normal_f32(0.0, 0.3);
    }
    (w, CalibStats::from_x(&x))
}

fn tmp_trace_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("thanos_trace_test_{}_{tag}.json", std::process::id()))
}

#[test]
fn tracer_lifecycle_end_to_end() {
    // --- 1. disabled by default, and disabled spans are cheap -------
    assert!(!trace::enabled(), "tracing must be off unless opted into");
    let t0 = trace::clock::now_nanos();
    for _ in 0..1_000_000 {
        let _s = trace::span("noop");
    }
    let disabled_secs = trace::clock::secs_since(t0);
    // one relaxed load + branch per span; bound is deliberately loose
    // (CI machines vary) while still catching accidental locking
    assert!(
        disabled_secs < 2.0,
        "1M disabled spans took {disabled_secs:.3}s — hot path regressed"
    );

    // --- 2. spans from engine workers land balanced in the export ---
    trace::set_enabled(true);
    {
        let eng = PruneEngine::with_threads(4);
        eng.run(64, |_i| {
            let _outer = trace::span("suite.task");
            let _inner = trace::span("suite.inner");
            std::hint::black_box(0u64);
        });
        // dropping the engine joins its workers; their thread-local
        // buffers spill to the registry on thread exit
    }
    trace::flush_local();
    let path = tmp_trace_path("engine");
    trace::export_to(&path).expect("export succeeds");
    let n_spans = check_chrome_trace(&path);
    assert!(
        n_spans >= 128,
        "expected >=128 closed spans (64 tasks x 2), got {n_spans}"
    );
    let aggs = trace::aggregate();
    let task = aggs
        .iter()
        .find(|a| a.name == "suite.task")
        .expect("suite.task aggregated");
    assert_eq!(task.count, 64);
    assert_eq!(task.hist.count(), 64);
    assert!(task.hist.quantile(0.5).is_some());
    std::fs::remove_file(&path).ok();

    // --- 3. spans stay balanced across a panicking task -------------
    {
        let eng = PruneEngine::with_threads(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            eng.run(16, |i| {
                let _s = trace::span("suite.panicky");
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "panic must propagate out of run()");
    }
    trace::flush_local();
    let path = tmp_trace_path("panic");
    trace::export_to(&path).expect("export after panic succeeds");
    check_chrome_trace(&path); // balance is the assertion
    std::fs::remove_file(&path).ok();

    // --- 4. tracing on does not perturb the prune walk --------------
    let (w, stats) = layer(48, 64, 96, 0x7A11);
    let opts = PruneOpts { block_size: 16, ..Default::default() };
    let pat = Pattern::Unstructured { p: 0.5 };
    let ser = engine::with_serial(|| prune(Method::Thanos, &w, &stats, pat, &opts)).unwrap();
    let par = prune(Method::Thanos, &w, &stats, pat, &opts).unwrap();
    assert_eq!(ser.mask, par.mask, "mask differs serial vs parallel with tracing on");
    let ser_bits: Vec<u32> = ser.w.data.iter().map(|v| v.to_bits()).collect();
    let par_bits: Vec<u32> = par.w.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(ser_bits, par_bits, "weights differ serial vs parallel with tracing on");

    // the walk recorded its stage spans
    let stages = trace::stage_totals();
    for name in ["walk.metric", "walk.select", "walk.solve", "walk.apply"] {
        assert!(
            stages.contains_key(name),
            "expected stage '{name}' in {:?}",
            stages.keys().collect::<Vec<_>>()
        );
    }

    trace::set_enabled(false);
    assert!(!trace::enabled());
}
