//! Property + integration tests for the `sparse/` subsystem: bitwise
//! pack→unpack round-trips across all three formats × random shapes,
//! kernel-vs-`gemm` equivalence at 1 and N threads, end-to-end
//! compression of a pruned model, and checkpoint v2 round-trips with
//! v1 back-compat (the CI smoke job runs this file).

use thanos::config::ModelConfig;
use thanos::linalg::gemm;
use thanos::linalg::Mat;
use thanos::model::ModelState;
use thanos::proptest::{check, dim, mat_heavy, Config};
use thanos::pruning::{self, CalibStats, Pattern, PruneOpts};
use thanos::rng::Rng;
use thanos::runtime::{ModelManifest, ParamEntry};
use thanos::sparse::{self, Csr, DenseCompact, NmPacked, SparseModel, SparseTensor};

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

/// Random matrix with exact zeros sprinkled in (plus the occasional
/// negative zero, which the formats must keep bitwise).
fn sparse_mat(r: &mut Rng, rows: usize, cols: usize, zero_frac: f64) -> Mat {
    let mut w = mat_heavy(r, rows, cols, 0.05);
    for v in w.data.iter_mut() {
        let u = r.uniform();
        if u < zero_frac {
            *v = 0.0;
        } else if u < zero_frac + 0.01 {
            *v = -0.0;
        }
    }
    w
}

#[test]
fn prop_csr_roundtrip_bitwise() {
    check(
        &Config { cases: 32, seed: 0x51 },
        |r| sparse_mat(r, dim(r, 1, 24), dim(r, 1, 31), r.uniform()),
        |w| {
            let t = Csr::from_dense(w);
            if bits(&t.to_dense()) != bits(w) {
                return Err("csr round-trip not bit-identical".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nm_roundtrip_bitwise_with_outliers() {
    check(
        &Config { cases: 32, seed: 0x52 },
        |r| {
            let (n, m) = *[(2usize, 4usize), (4, 8), (1, 2), (3, 4)]
                .get(r.below(4))
                .unwrap();
            let rows = dim(r, 1, 20);
            let cols = dim(r, 1, 5) * m;
            let w = mat_heavy(r, rows, cols, 0.05);
            let mut pruned = pruning::magnitude::semi_structured(&w, n, m).w;
            // leave a few rows dense (α-style outliers) + a kept -0.0
            for i in 0..rows {
                if r.uniform() < 0.2 {
                    pruned.row_mut(i).copy_from_slice(w.row(i));
                }
            }
            pruned.data[0] = -0.0;
            (pruned, n, m)
        },
        |(w, n, m)| {
            let t = NmPacked::from_dense(w, *n, *m).map_err(|e| e.to_string())?;
            if bits(&t.to_dense()) != bits(w) {
                return Err(format!("{n}:{m} round-trip not bit-identical"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dense_compact_roundtrip_bitwise() {
    check(
        &Config { cases: 32, seed: 0x53 },
        |r| {
            let rows = dim(r, 1, 18);
            let cols = dim(r, 2, 26);
            let w = mat_heavy(r, rows, cols, 0.05);
            let mut pruned = pruning::magnitude::structured(&w, 0.3 + r.uniform() * 0.4).w;
            for i in 0..rows {
                if r.uniform() < 0.2 {
                    pruned.row_mut(i).copy_from_slice(w.row(i)); // outlier row
                }
            }
            pruned
        },
        |w| {
            let t = DenseCompact::from_dense(w);
            if bits(&t.to_dense()) != bits(w) {
                return Err("dense-compact round-trip not bit-identical".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kernels_match_gemm_serial_and_parallel() {
    check(
        &Config { cases: 16, seed: 0x54 },
        |r| {
            let rows = dim(r, 2, 40);
            let cols = dim(r, 1, 6) * 8;
            let batch = dim(r, 1, 12);
            let w = mat_heavy(r, rows, cols, 0.05);
            let x = mat_heavy(r, cols, batch, 0.05);
            (w, x)
        },
        |(w, x)| {
            let cases: Vec<(String, SparseTensor)> = vec![
                (
                    "csr".into(),
                    SparseTensor::Csr(Csr::from_dense(&pruning::magnitude::unstructured(w, 0.6).w)),
                ),
                (
                    "nm".into(),
                    SparseTensor::Nm(
                        NmPacked::from_dense(&pruning::magnitude::semi_structured(w, 2, 4).w, 2, 4)
                            .map_err(|e| e.to_string())?,
                    ),
                ),
                (
                    "dc".into(),
                    SparseTensor::DenseCompact(DenseCompact::from_dense(
                        &pruning::magnitude::structured(w, 0.5).w,
                    )),
                ),
            ];
            for (label, t) in &cases {
                let dense = t.to_dense();
                let want = gemm::matmul(&dense, x);
                let par = t.matmul(x);
                let err = sparse::max_rel_err(&par, &want);
                if err > 1e-5 {
                    return Err(format!("{label}: parallel kernel err {err}"));
                }
                let ser = thanos::engine::with_serial(|| t.matmul(x));
                if bits(&par) != bits(&ser) {
                    return Err(format!("{label}: serial vs parallel not bit-identical"));
                }
            }
            Ok(())
        },
    );
}

// -- end-to-end: prune a model, compress every layer, checkpoint -----------

/// The micro-model manifest the model-state unit tests use, rebuilt
/// here (layer shapes 8x8 / 16x8 / 8x16 — all divisible by 8 for n:m).
fn micro_manifest() -> ModelManifest {
    let cfg = ModelConfig {
        name: "micro".into(),
        vocab: 16,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_ff: 16,
        seq_len: 4,
    };
    let mut layout = Vec::new();
    let mut off = 0usize;
    let mut push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>| {
        let numel: usize = shape.iter().product();
        layout.push(ParamEntry { name: name.into(), offset: off, shape });
        off += numel;
    };
    push(&mut layout, "emb", vec![16, 8]);
    push(&mut layout, "pos", vec![4, 8]);
    let mut block_flat = 0;
    for l in 0..2 {
        let before = layout.last().map(|e: &ParamEntry| e.offset + e.numel()).unwrap();
        push(&mut layout, &format!("blocks.{l}.ln1"), vec![8]);
        for w in ["wq", "wk", "wv", "wo"] {
            push(&mut layout, &format!("blocks.{l}.{w}"), vec![8, 8]);
        }
        push(&mut layout, &format!("blocks.{l}.ln2"), vec![8]);
        push(&mut layout, &format!("blocks.{l}.w1"), vec![16, 8]);
        push(&mut layout, &format!("blocks.{l}.w2"), vec![8, 16]);
        let after = layout.last().map(|e| e.offset + e.numel()).unwrap();
        block_flat = after - before;
    }
    push(&mut layout, "ln_f", vec![8]);
    let flat_size = layout.last().map(|e| e.offset + e.numel()).unwrap();
    ModelManifest { config: cfg, flat_size, block_flat_size: block_flat, layout }
}

/// Prune every prunable layer of a fresh micro model with the real
/// Thanos method at `pattern`.
fn pruned_micro(pattern: Pattern, seed: u64) -> ModelState {
    let mm = micro_manifest();
    let mut state = ModelState::init(&mm, seed);
    let opts = PruneOpts { block_size: 8, ..Default::default() };
    let mut r = Rng::new(seed ^ 0xCAFE);
    // calibration stats per input dim (8 and 16)
    let stats8 = CalibStats::from_x(&Mat::from_fn(8, 48, |_, _| r.normal_f32(0.0, 1.0)));
    let stats16 = CalibStats::from_x(&Mat::from_fn(16, 48, |_, _| r.normal_f32(0.0, 1.0)));
    for l in 0..state.config.n_layers {
        for name in state.prunable_layers(l) {
            let w = state.get_mat(&name).unwrap();
            let stats = if w.cols == 8 { &stats8 } else { &stats16 };
            let pruned =
                pruning::prune(pruning::Method::Thanos, &w, stats, pattern, &opts).unwrap();
            state.set_mat(&name, &pruned.w).unwrap();
        }
    }
    state
}

#[test]
fn e2e_compress_roundtrips_every_layer_and_every_pattern() {
    let patterns = [
        Pattern::Unstructured { p: 0.5 },
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.25 },
        Pattern::SemiStructured { n: 4, m: 8, alpha: 0.0 },
        Pattern::Structured { p: 0.3, alpha: 0.25 },
    ];
    for (k, pattern) in patterns.into_iter().enumerate() {
        let state = pruned_micro(pattern, 100 + k as u64);
        let sm = SparseModel::compress_state(&state, &pattern).unwrap();
        assert_eq!(sm.layers.len(), 12, "{pattern:?}");
        // exact round-trip on every pruned layer
        sm.verify_roundtrip(&state).unwrap();
        // kernels match the dense GEMM on every layer
        let mut r = Rng::new(7 + k as u64);
        for layer in &sm.layers {
            let w = state.get_mat(&layer.name).unwrap();
            let x = Mat::from_fn(w.cols, 5, |_, _| r.normal_f32(0.0, 1.0));
            let got = layer.tensor.matmul(&x);
            let want = gemm::matmul(&w, &x);
            let err = sparse::max_rel_err(&got, &want);
            assert!(err <= 1e-5, "{pattern:?} {}: err {err}", layer.name);
        }
        // n:m layers actually shrink storage
        if matches!(pattern, Pattern::SemiStructured { .. }) {
            assert!(
                sm.compressed_bytes() < sm.dense_bytes(),
                "{pattern:?}: {} !< {}",
                sm.compressed_bytes(),
                sm.dense_bytes()
            );
        }
    }
}

#[test]
fn v2_checkpoint_reloads_bit_identical() {
    let pattern = Pattern::SemiStructured { n: 2, m: 4, alpha: 0.25 };
    let state = pruned_micro(pattern, 200);
    let sm = SparseModel::compress_state(&state, &pattern).unwrap();
    let dir = std::env::temp_dir().join("thanos_sparse_itest_v2");
    let path = dir.join("micro-compressed.thnck");
    state.save_compressed(&path, &sm).unwrap();
    let (back, sparse) = ModelState::load_with_sparse(&path).unwrap();
    let fb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(fb(&back.flat), fb(&state.flat), "v2 reload must be bit-identical");
    let sparse = sparse.unwrap();
    assert_eq!(sparse.layers.len(), sm.layers.len());
    for (a, b) in sparse.layers.iter().zip(&sm.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.tensor, b.tensor, "serialized tensor changed for {}", a.name);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v1_dense_checkpoint_still_loads() {
    // back-compat gate: the pre-sparse checkpoint format keeps loading
    // through the same entry points (run by the CI smoke job)
    let state = pruned_micro(Pattern::Unstructured { p: 0.5 }, 300);
    let dir = std::env::temp_dir().join("thanos_sparse_itest_v1");
    let path = dir.join("micro.thnck");
    state.save(&path).unwrap();
    let loaded = ModelState::load(&path).unwrap();
    let fb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(fb(&loaded.flat), fb(&state.flat));
    let (again, sparse) = ModelState::load_with_sparse(&path).unwrap();
    assert!(sparse.is_none(), "a v1 file has no sparse tensors");
    assert_eq!(fb(&again.flat), fb(&state.flat));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compression_report_mentions_measured_and_modeled() {
    let pattern = Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 };
    let state = pruned_micro(pattern, 400);
    let sm = SparseModel::compress_state(&state, &pattern).unwrap();
    let report = thanos::eval::compression_report(&state, &sm).unwrap();
    assert!(report.contains("measured CPU"), "{report}");
    assert!(report.contains("modeled GPU"), "{report}");
    assert!(report.contains("nm(2:4)"), "{report}");
    assert!(report.contains("layers compressed"), "{report}");
}

#[test]
fn validator_and_packer_agree_on_outlier_budget() {
    // thanos n:m with α leaves ⌈αc⌉ dense rows; the packer must detect
    // at most that many outliers, and nm::validate must accept exactly
    // the packer's detected set
    let pattern = Pattern::SemiStructured { n: 2, m: 4, alpha: 0.25 };
    let state = pruned_micro(pattern, 500);
    for l in 0..state.config.n_layers {
        for name in state.prunable_layers(l) {
            let w = state.get_mat(&name).unwrap();
            let t = NmPacked::from_dense(&w, 2, 4).unwrap();
            let budget = (0.25f64 * w.rows as f64).ceil() as usize;
            assert!(
                t.outlier_rows.len() <= budget,
                "{name}: {} outliers > budget {budget}",
                t.outlier_rows.len()
            );
            let skip: pruning::nm::RowSet =
                t.outlier_rows.iter().map(|&r| r as usize).collect();
            pruning::nm::validate(&w, 2, 4, &skip).unwrap();
        }
    }
}
