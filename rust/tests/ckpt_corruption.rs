//! Corruption fuzz for checkpoint IO (DESIGN.md §Robustness): v3's
//! CRC-64 section framing must turn **every** single-bit flip and
//! **every** truncation into a descriptive `Err` — never a panic, never
//! a silently-wrong load. Legacy v1/v2 files must never panic either
//! (they predate the checksums, so silent flips are possible — one test
//! demonstrates exactly the corruption v3 catches and v1 misses), and
//! raw [`SparseTensor`] blobs must survive arbitrary mutation without
//! panicking.
//!
//! Everything is exhaustive rather than sampled: the micro checkpoint
//! is a few KB, so all `8 × len` flips and all `len` truncations parse
//! in well under a second per format.

use std::panic::{catch_unwind, AssertUnwindSafe};

use thanos::config::ModelConfig;
use thanos::model::ModelState;
use thanos::pruning::{magnitude, Pattern};
use thanos::runtime::{ModelManifest, ParamEntry};
use thanos::sparse::{SparseModel, SparseTensor};

fn micro_manifest() -> ModelManifest {
    let cfg = ModelConfig {
        name: "micro".into(),
        vocab: 16,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_ff: 16,
        seq_len: 4,
    };
    let mut layout = Vec::new();
    let mut off = 0usize;
    let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
        let numel: usize = shape.iter().product();
        layout.push(ParamEntry { name: name.into(), offset: *off, shape });
        *off += numel;
    };
    push(&mut layout, "emb", vec![16, 8], &mut off);
    push(&mut layout, "pos", vec![4, 8], &mut off);
    let mut block_flat = 0;
    for l in 0..cfg.n_layers {
        let before = off;
        push(&mut layout, &format!("blocks.{l}.ln1"), vec![8], &mut off);
        for w in ["wq", "wk", "wv", "wo"] {
            push(&mut layout, &format!("blocks.{l}.{w}"), vec![8, 8], &mut off);
        }
        push(&mut layout, &format!("blocks.{l}.ln2"), vec![8], &mut off);
        push(&mut layout, &format!("blocks.{l}.w1"), vec![16, 8], &mut off);
        push(&mut layout, &format!("blocks.{l}.w2"), vec![8, 16], &mut off);
        block_flat = off - before;
    }
    push(&mut layout, "ln_f", vec![8], &mut off);
    ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
}

/// A 2:4-pruned micro state plus its compressed form — what the real
/// pipeline checkpoints.
fn pruned_state() -> (ModelState, SparseModel) {
    let mm = micro_manifest();
    let mut st = ModelState::init(&mm, 7);
    for l in 0..mm.config.n_layers {
        for name in st.prunable_layers(l) {
            let w = st.get_mat(&name).unwrap();
            st.set_mat(&name, &magnitude::semi_structured(&w, 2, 4).w).unwrap();
        }
    }
    let pattern = Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 };
    let sm = SparseModel::compress_state(&st, &pattern).unwrap();
    (st, sm)
}

fn save_bytes(save: impl FnOnce(&std::path::Path)) -> Vec<u8> {
    let dir = std::env::temp_dir().join(format!("thanos-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.thnck");
    save(&path);
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    bytes
}

/// `from_bytes` under `catch_unwind`: `Some(err)` if it returned an
/// error, `None` if it loaded; panics of any kind fail the test here.
fn try_load(bytes: &[u8], what: &str) -> Option<String> {
    let res = catch_unwind(AssertUnwindSafe(|| ModelState::from_bytes(bytes).map(|_| ())));
    match res {
        Ok(Ok(())) => None,
        Ok(Err(e)) => Some(format!("{e:#}")),
        Err(_) => panic!("{what}: loader panicked instead of returning Err"),
    }
}

#[test]
fn v3_rejects_every_single_bit_flip() {
    let (st, sm) = pruned_state();
    let bytes = save_bytes(|p| st.save_compressed(p, &sm).unwrap());
    assert!(try_load(&bytes, "pristine v3").is_none(), "pristine file must load");
    let mut work = bytes.clone();
    for i in 0..work.len() {
        for bit in 0..8 {
            work[i] ^= 1 << bit;
            let what = format!("v3 flip byte {i} bit {bit}");
            assert!(
                try_load(&work, &what).is_some(),
                "{what}: corrupt checkpoint loaded successfully"
            );
            work[i] ^= 1 << bit;
        }
    }
    assert_eq!(work, bytes, "fuzz loop must restore the buffer");
}

#[test]
fn v3_rejects_every_truncation() {
    let (st, sm) = pruned_state();
    let bytes = save_bytes(|p| st.save_compressed(p, &sm).unwrap());
    for len in 0..bytes.len() {
        let what = format!("v3 truncated to {len} bytes");
        assert!(
            try_load(&bytes[..len], &what).is_some(),
            "{what}: truncated checkpoint loaded successfully"
        );
    }
}

#[test]
fn legacy_v1_v2_never_panic_under_corruption() {
    let (st, sm) = pruned_state();
    for (tag, bytes) in [
        ("v1", save_bytes(|p| st.save_v1(p).unwrap())),
        ("v2", save_bytes(|p| st.save_v2(p, &sm).unwrap())),
    ] {
        assert!(try_load(&bytes, tag).is_none(), "pristine {tag} must load");
        for len in 0..bytes.len() {
            let what = format!("{tag} truncated to {len} bytes");
            assert!(
                try_load(&bytes[..len], &what).is_some(),
                "{what}: truncated checkpoint loaded successfully"
            );
        }
        // Flips may load (these formats predate the checksums) but must
        // never panic — try_load fails the test on any panic.
        let mut work = bytes.clone();
        for i in 0..work.len() {
            for bit in 0..8 {
                work[i] ^= 1 << bit;
                try_load(&work, &format!("{tag} flip byte {i} bit {bit}"));
                work[i] ^= 1 << bit;
            }
        }
    }
}

/// The upgrade rationale in one test: a mantissa bit-flip in a v1 file
/// loads "successfully" with a silently different weight, while the
/// same payload flip in the v3 encoding of the same state is caught by
/// the section CRC.
#[test]
fn v3_catches_the_payload_flip_v1_silently_accepts() {
    let mm = micro_manifest();
    let st = ModelState::init(&mm, 9);

    let mut v1 = save_bytes(|p| st.save_v1(p).unwrap());
    let i = v1.len() - 4; // LSB of the last float's little-endian bytes
    v1[i] ^= 1;
    let (loaded, _) = ModelState::from_bytes(&v1).expect("v1 has no checksum to object with");
    assert_ne!(
        loaded.flat.last().unwrap().to_bits(),
        st.flat.last().unwrap().to_bits(),
        "the flip must have landed in the last weight"
    );

    let mut v3 = save_bytes(|p| st.save(p).unwrap());
    let i = v3.len() - 4;
    v3[i] ^= 1;
    let err = ModelState::from_bytes(&v3).unwrap_err();
    assert!(format!("{err:#}").contains("CRC-64"), "unexpected error: {err:#}");
}

#[test]
fn sparse_blobs_reject_truncation_and_never_panic_on_mutation() {
    let mm = micro_manifest();
    let mut st = ModelState::init(&mm, 11);
    for l in 0..mm.config.n_layers {
        for name in st.prunable_layers(l) {
            let w = st.get_mat(&name).unwrap();
            st.set_mat(&name, &magnitude::semi_structured(&w, 2, 4).w).unwrap();
        }
    }
    // one blob per wire format: 2:4 → NmPacked, unstructured → Csr,
    // structured → DenseCompact
    let patterns = [
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
        Pattern::Unstructured { p: 0.5 },
        Pattern::Structured { p: 0.5, alpha: 0.0 },
    ];
    for pattern in patterns {
        let sm = SparseModel::compress_state(&st, &pattern).unwrap();
        let tensor = &sm.layers[0].tensor;
        let blob = tensor.to_bytes();
        let label = tensor.label();

        let back = SparseTensor::from_bytes(&blob)
            .unwrap_or_else(|e| panic!("{label}: pristine blob rejected: {e:#}"));
        assert_eq!((back.rows(), back.cols()), (tensor.rows(), tensor.cols()));

        for len in 0..blob.len() {
            let res = catch_unwind(AssertUnwindSafe(|| SparseTensor::from_bytes(&blob[..len])));
            match res {
                Ok(r) => assert!(r.is_err(), "{label}: {len}-byte truncation parsed"),
                Err(_) => panic!("{label}: {len}-byte truncation panicked"),
            }
        }

        // Mutations may parse (blob integrity is the enclosing v3
        // section's job) but must never panic, and whatever parses must
        // be structurally sound enough to densify. Densify only when
        // the claimed shape is the expected one, exactly like the
        // checkpoint loader does — a flipped dimension field can
        // honestly describe an absurdly large (all-zero) tensor.
        let (rows, cols) = (tensor.rows(), tensor.cols());
        let mut work = blob.clone();
        for i in 0..work.len() {
            for bit in 0..8 {
                work[i] ^= 1 << bit;
                let res = catch_unwind(AssertUnwindSafe(|| {
                    if let Ok(t) = SparseTensor::from_bytes(&work) {
                        if (t.rows(), t.cols()) == (rows, cols) {
                            let d = t.to_dense();
                            assert_eq!((d.rows, d.cols), (rows, cols));
                        }
                    }
                }));
                assert!(res.is_ok(), "{label}: flip byte {i} bit {bit} panicked");
                work[i] ^= 1 << bit;
            }
        }
    }
}
