//! Integration tests over the AOT artifacts: the JAX/Pallas HLO path
//! executed through the PJRT runtime, cross-validated against the
//! pure-Rust implementations.
//!
//! These tests need `make artifacts` to have run (they are skipped with
//! a notice otherwise, so `cargo test` works in a fresh checkout).

use thanos::coordinator::{Backend, Coordinator, PruneSpec};
use thanos::data::{Corpus, CorpusConfig};
use thanos::eval;
use thanos::linalg::gemm::recon_loss;
use thanos::linalg::Mat;
use thanos::model::ModelState;
use thanos::pruning::{self, CalibStats, Method, Pattern, PruneOpts};
use thanos::rng::Rng;
use thanos::runtime::{lit_f32, lit_scalar_f32, lit_scalar_i32, mat_lit, to_mat, to_vec_f32, Runtime};
use thanos::train::Trainer;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load("artifacts").expect("loading runtime"))
}

/// Correlated calibration setup at an artifact shape.
fn setup(c: usize, b: usize, a: usize, seed: u64) -> (Mat, CalibStats, Mat) {
    let mut r = Rng::new(seed);
    let w = Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
    let k = b / 4;
    let factors = Mat::from_fn(k, a, |_, _| r.normal_f32(0.0, 1.0));
    let loading = Mat::from_fn(b, k, |_, _| r.normal_f32(0.0, 0.3));
    let mut x = thanos::linalg::gemm::matmul(&loading, &factors);
    for v in x.data.iter_mut() {
        *v += r.normal_f32(0.0, 0.3);
    }
    let stats = CalibStats::from_x(&x);
    (w, stats, x)
}

fn h_f32(stats: &CalibStats) -> Vec<f32> {
    stats.h_sum.data.iter().map(|&v| v as f32).collect()
}

fn xn_f32(stats: &CalibStats) -> Vec<f32> {
    stats.xnorm_sq.iter().map(|&v| v as f32).collect()
}

#[test]
fn aot_wanda_matches_rust() {
    let Some(rt) = runtime() else { return };
    let (c, b) = (128, 128);
    let (w, stats, _) = setup(c, b, 300, 1);
    let out = rt
        .exec(
            &format!("prune_wanda_{c}x{b}"),
            &[
                mat_lit(&w).unwrap(),
                lit_f32(&xn_f32(&stats), &[b]).unwrap(),
                lit_scalar_i32((b / 2) as i32),
            ],
        )
        .unwrap();
    let w_aot = to_mat(&out[0], c, b).unwrap();
    let w_rust = pruning::wanda::unstructured(&w, &stats, 0.5).w;
    // same masks (ties are measure-zero with random data), same values
    let diff = w_aot.max_abs_diff(&w_rust);
    assert!(diff < 1e-5, "wanda AOT vs Rust diff {diff}");
}

#[test]
fn aot_magnitude_matches_rust() {
    let Some(rt) = runtime() else { return };
    let (c, b) = (128, 128);
    let (w, _, _) = setup(c, b, 300, 2);
    let r = (c * b) / 2;
    let out = rt
        .exec(
            &format!("prune_magnitude_{c}x{b}"),
            &[mat_lit(&w).unwrap(), lit_scalar_i32(r as i32)],
        )
        .unwrap();
    let w_aot = to_mat(&out[0], c, b).unwrap();
    let w_rust = pruning::magnitude::unstructured(&w, 0.5).w;
    assert!(w_aot.max_abs_diff(&w_rust) < 1e-6);
}

#[test]
fn aot_hessian_accum_matches_rust_stats() {
    let Some(rt) = runtime() else { return };
    let b = 128;
    let a = 1024; // the artifact's chunk size
    let mut r = Rng::new(3);
    let xt: Vec<f32> = (0..a * b).map(|_| r.normal_f32(0.0, 1.0)).collect();
    let h0 = vec![0.0f32; b * b];
    let out = rt
        .exec(
            &format!("hessian_accum_{b}"),
            &[lit_f32(&h0, &[b, b]).unwrap(), lit_f32(&xt, &[a, b]).unwrap()],
        )
        .unwrap();
    let h_aot = to_vec_f32(&out[0]).unwrap();
    let xn_aot = to_vec_f32(&out[1]).unwrap();
    // Rust: X = transpose(xt)
    let xmat = Mat::from_vec(a, b, xt).transpose();
    let stats = CalibStats::from_x(&xmat);
    for i in 0..b * b {
        let rel = (h_aot[i] as f64 - stats.h_sum.data[i]).abs()
            / stats.h_sum.data[i].abs().max(1.0);
        assert!(rel < 1e-3, "H[{i}] {} vs {}", h_aot[i], stats.h_sum.data[i]);
    }
    for j in 0..b {
        let rel = (xn_aot[j] as f64 - stats.xnorm_sq[j]).abs() / stats.xnorm_sq[j].max(1.0);
        assert!(rel < 1e-3);
    }
}

#[test]
fn aot_thanos_unstructured_close_to_rust() {
    let Some(rt) = runtime() else { return };
    let (c, b) = (128, 128);
    let (w, stats, x) = setup(c, b, 300, 4);
    let name = rt
        .manifest
        .executables
        .keys()
        .find(|k| k.starts_with(&format!("prune_thanos_unstr_{c}x{b}_B")))
        .cloned()
        .expect("thanos unstr artifact");
    let out = rt
        .exec(
            &name,
            &[
                mat_lit(&w).unwrap(),
                lit_f32(&h_f32(&stats), &[b, b]).unwrap(),
                lit_f32(&xn_f32(&stats), &[b]).unwrap(),
                lit_scalar_f32(0.5),
            ],
        )
        .unwrap();
    let w_aot = to_mat(&out[0], c, b).unwrap();
    let sp = w_aot.sparsity();
    assert!((sp - 0.5).abs() < 0.02, "AOT thanos sparsity {sp}");
    // quality parity with the Rust implementation (f32 vs f64 paths)
    let opts = PruneOpts { block_size: 128, ..Default::default() };
    let w_rust = pruning::thanos::unstructured(&w, &stats, 0.5, &opts).unwrap().w;
    let l_aot = recon_loss(&w_aot, &w, &x);
    let l_rust = recon_loss(&w_rust, &w, &x);
    assert!(
        l_aot < l_rust * 1.25 + 1e-6,
        "AOT loss {l_aot} vs Rust {l_rust}"
    );
    // and it must beat Wanda (update matters)
    let l_wanda = recon_loss(&pruning::wanda::unstructured(&w, &stats, 0.5).w, &w, &x);
    assert!(l_aot < l_wanda, "AOT thanos {l_aot} !< wanda {l_wanda}");
}

#[test]
fn aot_thanos_structured_columns() {
    let Some(rt) = runtime() else { return };
    let (c, b) = (128, 128);
    let (w, stats, _) = setup(c, b, 300, 5);
    let out = rt
        .exec(
            &format!("prune_thanos_struct_{c}x{b}"),
            &[
                mat_lit(&w).unwrap(),
                lit_f32(&h_f32(&stats), &[b, b]).unwrap(),
                lit_f32(&xn_f32(&stats), &[b]).unwrap(),
                lit_scalar_f32(0.3),
                lit_scalar_f32(0.1),
            ],
        )
        .unwrap();
    let w_aot = to_mat(&out[0], c, b).unwrap();
    // expected: ceil(0.1*128)=13 outlier rows untouched; others share a
    // removed-column set of size ceil(0.3*128/0.9)=43
    let untouched: Vec<usize> = (0..c)
        .filter(|&i| w_aot.row(i) == w.row(i))
        .collect();
    assert_eq!(untouched.len(), 13, "outlier rows");
    let pruned_rows: Vec<usize> = (0..c).filter(|i| !untouched.contains(i)).collect();
    let removed: Vec<usize> = (0..b)
        .filter(|&j| pruned_rows.iter().all(|&i| w_aot.at(i, j) == 0.0))
        .collect();
    assert_eq!(removed.len(), 43, "removed columns");
}

#[test]
fn aot_thanos_nm_format() {
    let Some(rt) = runtime() else { return };
    let (c, b) = (128, 128);
    let (w, stats, x) = setup(c, b, 300, 6);
    let name = rt
        .manifest
        .executables
        .keys()
        .find(|k| k.starts_with(&format!("prune_thanos_nm_{c}x{b}_2_4_B")))
        .cloned()
        .expect("thanos nm artifact");
    let out = rt
        .exec(
            &name,
            &[
                mat_lit(&w).unwrap(),
                lit_f32(&h_f32(&stats), &[b, b]).unwrap(),
                lit_f32(&xn_f32(&stats), &[b]).unwrap(),
                lit_scalar_f32(0.0),
            ],
        )
        .unwrap();
    let w_aot = to_mat(&out[0], c, b).unwrap();
    pruning::nm::validate(&w_aot, 2, 4, &pruning::nm::RowSet::new()).expect("2:4 format");
    // joint update keeps it ahead of wanda 2:4
    let l_aot = recon_loss(&w_aot, &w, &x);
    let l_wanda = recon_loss(&pruning::wanda::semi_structured(&w, &stats, 2, 4).w, &w, &x);
    assert!(l_aot < l_wanda);
}

#[test]
fn train_step_reduces_loss_tiny() {
    let Some(rt) = runtime() else { return };
    let Ok(mm) = rt.model("tiny") else {
        eprintln!("SKIP: tiny model not in artifacts");
        return;
    };
    let corpus = Corpus::build(&CorpusConfig {
        seq_len: mm.config.seq_len,
        train_seqs: 64,
        calib_seqs: 8,
        eval_seqs: 8,
        ..Default::default()
    });
    let state = ModelState::init(mm, 99);
    let mut trainer = Trainer::new(&rt, state, 2e-3).unwrap();
    let log = trainer.train(&corpus, 12, 7).unwrap();
    let first = log[0].loss;
    let last = log.last().unwrap().loss;
    assert!(
        last < first - 0.1,
        "loss did not fall: {first} -> {last}"
    );
    assert!((first - (mm.config.vocab as f32).ln()).abs() < 1.0);
}

#[test]
fn full_pipeline_prune_tiny_wanda_and_thanos() {
    let Some(rt) = runtime() else { return };
    let Ok(mm) = rt.model("tiny") else { return };
    let corpus = Corpus::build(&CorpusConfig {
        seq_len: mm.config.seq_len,
        train_seqs: 64,
        calib_seqs: 16,
        eval_seqs: 8,
        ..Default::default()
    });
    // brief training so pruning has signal to destroy
    let state0 = ModelState::init(mm, 5);
    let mut trainer = Trainer::new(&rt, state0, 2e-3).unwrap();
    trainer.train(&corpus, 20, 11).unwrap();
    let base = trainer.state.clone();
    let ppl_dense = eval::perplexity(&rt, &base, &corpus.eval).unwrap();
    assert!(ppl_dense.is_finite() && ppl_dense > 1.0);

    for (method, backend) in [
        (Method::Wanda, Backend::Aot),
        (Method::Thanos, Backend::Aot),
        (Method::SparseGpt, Backend::Aot), // exercises the Rust fallback
    ] {
        let mut state = base.clone();
        let spec = PruneSpec {
            method,
            pattern: Pattern::Unstructured { p: 0.5 },
            opts: PruneOpts::default(),
            backend,
        };
        let report = Coordinator::new(&rt)
            .prune_model(&mut state, &corpus.calib, &spec)
            .unwrap();
        let sp = report.overall_sparsity();
        assert!(
            (sp - 0.5).abs() < 0.02,
            "{} sparsity {sp}",
            method.name()
        );
        assert_eq!(report.layers.len(), mm.config.n_layers * 6);
        let ppl = eval::perplexity(&rt, &state, &corpus.eval).unwrap();
        assert!(
            ppl.is_finite() && ppl >= ppl_dense * 0.8,
            "{}: ppl {ppl} vs dense {ppl_dense}",
            method.name()
        );
    }
}

#[test]
fn zero_shot_suite_runs_on_tiny() {
    let Some(rt) = runtime() else { return };
    let Ok(mm) = rt.model("tiny") else { return };
    let corpus = Corpus::build(&CorpusConfig {
        seq_len: mm.config.seq_len,
        train_seqs: 8,
        calib_seqs: 8,
        eval_seqs: 8,
        ..Default::default()
    });
    let state = ModelState::init(mm, 17);
    let results = eval::zero_shot_suite(&rt, &state, &corpus.grammar, 12, 3).unwrap();
    assert_eq!(results.len(), 7);
    for (t, acc) in &results {
        assert!((0.0..=1.0).contains(acc), "{}: {acc}", t.name());
    }
}
