//! §Perf-L5 property tests: the threshold-select engine pinned bitwise
//! against the `(value, index)` select_nth oracle (heavy ties at θ,
//! ±0.0, boundary ranks, serial == parallel), and the interleaved
//! batched Cholesky pinned bitwise against the per-row solve on
//! gathered systems across the interleave/per-row crossover.

use thanos::linalg::batched::{
    solve_band_padded_into_panel, solve_row_in_scratch, PanelSolveScratch, RowSolveScratch,
};
use thanos::linalg::chol::{chol_inverse, damp_hessian};
use thanos::linalg::gemm::xxt_f64;
use thanos::linalg::Mat;
use thanos::pruning::metric::smallest_r_mask_into;
use thanos::pruning::select::{smallest_r_mask_threshold_into, SelectScratch};
use thanos::rng::Rng;

fn assert_matches_oracle(metric: &[f64], r: usize, scratch: &mut SelectScratch, tag: &str) {
    let mut oracle = Vec::new();
    smallest_r_mask_into(metric, r, &mut oracle);
    let mut got = Vec::new();
    smallest_r_mask_threshold_into(metric, r, &mut got, scratch);
    assert_eq!(oracle, got, "{tag}: r={r} n={}", metric.len());
    let serial = thanos::engine::with_serial(|| {
        let mut m = Vec::new();
        smallest_r_mask_threshold_into(metric, r, &mut m, scratch);
        m
    });
    assert_eq!(oracle, serial, "{tag} serial: r={r} n={}", metric.len());
}

#[test]
fn threshold_select_matches_oracle_random() {
    let mut rng = Rng::new(0xA11);
    let mut scratch = SelectScratch::new();
    for trial in 0..25 {
        let n = 1 + rng.below(30_000);
        let metric: Vec<f64> = (0..n).map(|_| rng.normal().abs() * 3.0).collect();
        for r in [0, 1, n / 2, n.saturating_sub(1), n, rng.below(n + 1)] {
            assert_matches_oracle(&metric, r, &mut scratch, &format!("random t{trial}"));
        }
    }
}

#[test]
fn threshold_select_matches_oracle_heavy_ties() {
    // duplicated values, mixed ±0.0 (one partial_cmp tie class — the
    // oracle breaks both by index), tiny alphabet, all-equal
    let mut rng = Rng::new(0xA12);
    let mut scratch = SelectScratch::new();
    for trial in 0..25 {
        let n = 1 + rng.below(10_000);
        let metric: Vec<f64> = (0..n)
            .map(|_| match rng.below(6) {
                0 => 0.0,
                1 => -0.0,
                2 => 0.5,
                3 => (rng.below(4) as f64) * 0.125,
                4 => 1e-300,
                _ => -((rng.below(3) + 1) as f64) * 0.75,
            })
            .collect();
        for r in [0, 1, n / 3, n / 2, n.saturating_sub(1), n] {
            assert_matches_oracle(&metric, r, &mut scratch, &format!("ties t{trial}"));
        }
    }
}

#[test]
fn threshold_select_wanda_shaped_metric_multi_band() {
    // the actual hot-path shape: |W| · ‖X‖ over a c×rest window, sized
    // past the 2¹⁷-cell band floor so the engine splits into several
    // bands (the cross-band below/tie accounting is live, not the
    // single-band collapse)
    let mut rng = Rng::new(0xA13);
    let mut scratch = SelectScratch::new();
    let (c, rest) = (1200, 256); // 307_200 cells ≥ 2 bands
    let norms: Vec<f64> = (0..rest).map(|_| rng.normal().abs() + 0.1).collect();
    let metric: Vec<f64> = (0..c * rest)
        .map(|k| (rng.normal_f32(0.0, 1.0).abs() as f64) * norms[k % rest])
        .collect();
    for r in [0, 1, c * rest / 2, c * rest - 1, c * rest] {
        assert_matches_oracle(&metric, r, &mut scratch, "wanda");
    }
}

#[test]
fn threshold_select_multi_band_boundary_ties() {
    // tie runs straddling the band boundaries: a tiny value alphabet
    // over 300k cells forces every band to carry ties of θ, so the
    // ascending quota prefix (and the per-band tie top-up) is what
    // produces the mask — any cross-band accounting slip diverges from
    // the oracle immediately
    let mut rng = Rng::new(0xA15);
    let mut scratch = SelectScratch::new();
    let n = 300_000;
    let metric: Vec<f64> = (0..n)
        .map(|_| match rng.below(4) {
            0 => 1.0,
            1 => 2.0,
            2 => 0.0,
            _ => rng.normal().abs(),
        })
        .collect();
    for r in [0, 1, n / 4, n / 2, 123_457, n - 1, n] {
        assert_matches_oracle(&metric, r, &mut scratch, "boundary-ties");
    }
    // one tie class across every band: the quota spans band boundaries
    let flat = vec![4.5f64; n];
    let mut mask = Vec::new();
    smallest_r_mask_threshold_into(&flat, 200_000, &mut mask, &mut scratch);
    for (i, &m) in mask.iter().enumerate() {
        assert_eq!(m, i < 200_000, "flat index {i}");
    }
}

#[test]
fn threshold_select_dense_single_bucket_window() {
    // 200k distinct-ish values inside ONE top-level bucket (same
    // exponent, same leading mantissa bits): the candidate window is
    // essentially the whole input, so the range-histogram refinement
    // loop (retain + rank adjustment) is what narrows to θ
    let mut rng = Rng::new(0xA16);
    let mut scratch = SelectScratch::new();
    let n = 200_000;
    let metric: Vec<f64> = (0..n).map(|_| 1.0 + rng.uniform() * 1e-5).collect();
    for r in [1, n / 2, n - 1] {
        assert_matches_oracle(&metric, r, &mut scratch, "dense-bucket");
    }
}

#[test]
fn threshold_select_extreme_ranges_and_tiny_inputs() {
    let mut scratch = SelectScratch::new();
    assert_matches_oracle(&[2.5], 0, &mut scratch, "single0");
    assert_matches_oracle(&[2.5], 1, &mut scratch, "single1");
    let metric = vec![f64::MAX, f64::MIN_POSITIVE, 0.0, 1e308, 5e-324, -f64::MAX];
    for r in 0..=metric.len() {
        assert_matches_oracle(&metric, r, &mut scratch, "extreme");
    }
    // a window larger than the refinement threshold with one tie class
    let big = vec![7.0f64; 70_000];
    assert_matches_oracle(&big, 12_345, &mut scratch, "bigtie");
}

fn gathered_hinv(b: usize, seed: u64) -> thanos::linalg::MatF64 {
    let mut r = Rng::new(seed);
    let x = Mat::from_fn(b, b + 7, |_, _| r.normal_f32(0.0, 1.0));
    let mut h = xxt_f64(&x);
    for v in h.data.iter_mut() {
        *v *= 2.0;
    }
    damp_hessian(&mut h, 0.01);
    chol_inverse(&h).unwrap()
}

#[test]
fn interleaved_batch_bitwise_equals_per_row_solves() {
    // random support sets spanning the interleave/per-row crossover
    // (sizes 1..=40 with the dispatch boundary at 24), batched through
    // the band solver and pinned bit-for-bit against the per-row sweep
    let hinv = gathered_hinv(64, 0xB01);
    let mut rng = Rng::new(0xB02);
    for trial in 0..12 {
        let rows = 1 + rng.below(40);
        let width = 64;
        let mut qs: Vec<Vec<usize>> = Vec::new();
        for _ in 0..rows {
            if rng.below(8) == 0 {
                qs.push(Vec::new()); // empty supports must stay zero rows
                continue;
            }
            let sz = 1 + rng.below(40);
            let mut q = rng.choose_k(width, sz.min(width));
            q.sort_unstable();
            qs.push(q);
        }
        let us: Vec<Vec<f64>> =
            qs.iter().map(|q| q.iter().map(|_| rng.normal()).collect()).collect();
        let mut ps = PanelSolveScratch::new();
        ps.begin(qs.len(), width);
        for (q, u) in qs.iter().zip(&us) {
            for (&k, &v) in q.iter().zip(u) {
                ps.push(k, v);
            }
            ps.end_row();
        }
        solve_band_padded_into_panel(&hinv, &mut ps).unwrap();
        for (ri, (q, u)) in qs.iter().zip(&us).enumerate() {
            let mut s = RowSolveScratch::new();
            s.q.extend_from_slice(q);
            s.u.extend_from_slice(u);
            solve_row_in_scratch(&hinv, &mut s).unwrap();
            let lrow = &ps.lam[ri * width..(ri + 1) * width];
            let mut expect = vec![0.0f64; width];
            for (t, &qt) in q.iter().enumerate() {
                expect[qt] = s.lam[t];
            }
            for (k, (&got, &want)) in lrow.iter().zip(&expect).enumerate() {
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "trial {trial} row {ri} slot {k}: batched {got} vs per-row {want}"
                );
            }
        }
    }
}

#[test]
fn interleaved_batch_serial_parallel_bit_identical() {
    // the whole band solve (sorting, batching, padding included) must
    // be independent of the engine mode
    let hinv = gathered_hinv(48, 0xB03);
    let mut rng = Rng::new(0xB04);
    let width = 48;
    let mut ps = PanelSolveScratch::new();
    ps.begin(30, width);
    let mut qs: Vec<Vec<usize>> = Vec::new();
    for _ in 0..30 {
        let sz = 1 + rng.below(20);
        let mut q = rng.choose_k(width, sz);
        q.sort_unstable();
        for &k in &q {
            ps.push(k, rng.normal());
        }
        ps.end_row();
        qs.push(q);
    }
    solve_band_padded_into_panel(&hinv, &mut ps).unwrap();
    let lam_par = ps.lam.clone();
    // re-record (begin clears) and solve under forced-serial execution
    let lam_ser = thanos::engine::with_serial(|| {
        let mut ps2 = PanelSolveScratch::new();
        ps2.begin(30, width);
        let mut rng2 = Rng::new(0xB04);
        for _ in 0..30 {
            let sz = 1 + rng2.below(20);
            let mut q = rng2.choose_k(width, sz);
            q.sort_unstable();
            for &k in &q {
                ps2.push(k, rng2.normal());
            }
            ps2.end_row();
        }
        solve_band_padded_into_panel(&hinv, &mut ps2).unwrap();
        ps2.lam.clone()
    });
    let a: Vec<u64> = lam_par.iter().map(|v| v.to_bits()).collect();
    let b: Vec<u64> = lam_ser.iter().map(|v| v.to_bits()).collect();
    assert_eq!(a, b, "band solve must not depend on engine mode");
}
