//! Chaos harness for the bounded-memory streaming prune pipeline
//! (DESIGN.md §Streaming): kill the run at every streaming fault site
//! (`stream.read`, `stream.verify`, `stream.prefetch`, `governor.admit`,
//! `pipeline.stage`) — panics, transient IO errors, and a real
//! `process::exit` in a subprocess — then `--resume` and assert the
//! final weights and progress-checkpoint **bytes** are identical to an
//! uninterrupted all-in-RAM run, across patterns and serial/parallel
//! execution. Plus container fuzzing (chunk-table bit flips and
//! truncations, mirroring `ckpt_corruption.rs`) and governor
//! backpressure/accounting checks.
//!
//! The walk is driven through a synthetic [`ChunkOps`] so no AOT
//! artifacts are needed: `embed` reads only unpruned params and
//! `forward` folds a digest of the block's **current** weights into the
//! activations — later blocks genuinely depend on earlier pruning
//! decisions, so a resume or a streamed replay that restored the wrong
//! bytes would diverge.
//!
//! Fault schedules are process-global, so every test serializes on one
//! lock.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{ensure, Result};
use thanos::config::ModelConfig;
use thanos::coordinator::{
    progress_ckpt_path, run_pruning, Backend, ChunkForward, ChunkOps, PruneReport, PruneSpec,
    RobustOpts, StreamOpts, StreamingPipeline,
};
use thanos::model::ModelState;
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::robust::faults;
use thanos::robust::{crc64_f32s, ChunkReader, ChunkWriter, STREAM_SITES};
use thanos::runtime::{ModelManifest, ParamEntry};

/// Fault schedules are process-global state: every test takes this.
static LOCK: Mutex<()> = Mutex::new(());

const SEED: u64 = 0x57E4;
const CHILD_ENV: &str = "THANOS_STREAM_CHILD";
/// Activation-chunk bytes of [`SynthOps`]: a·d·4.
const CHUNK_BYTES: u64 = (A * D * 4) as u64;
/// The structural floor — one chunk queued, one held by the prefetch
/// stage, one in consumption — so the budget is a true in-flight bound.
const BUDGET: u64 = 3 * CHUNK_BYTES;

const A: usize = 16;
const D: usize = 8;
const D_FF: usize = 16;
const CHUNKS: usize = 4;

// ------------------------------------------------------------------
// synthetic model + chunk ops

/// Micro 3-block manifest mirroring the python param_specs layout.
fn micro_manifest() -> ModelManifest {
    let cfg = ModelConfig {
        name: "micro3".into(),
        vocab: 16,
        d_model: D,
        n_layers: 3,
        n_heads: 2,
        d_ff: D_FF,
        seq_len: 4,
    };
    let mut layout = Vec::new();
    let mut off = 0usize;
    let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
        let numel: usize = shape.iter().product();
        layout.push(ParamEntry { name: name.into(), offset: *off, shape });
        *off += numel;
    };
    push(&mut layout, "emb", vec![16, D], &mut off);
    push(&mut layout, "pos", vec![4, D], &mut off);
    let mut block_flat = 0;
    for l in 0..cfg.n_layers {
        let before = off;
        push(&mut layout, &format!("blocks.{l}.ln1"), vec![D], &mut off);
        for w in ["wq", "wk", "wv", "wo"] {
            push(&mut layout, &format!("blocks.{l}.{w}"), vec![D, D], &mut off);
        }
        push(&mut layout, &format!("blocks.{l}.ln2"), vec![D], &mut off);
        push(&mut layout, &format!("blocks.{l}.w1"), vec![D_FF, D], &mut off);
        push(&mut layout, &format!("blocks.{l}.w2"), vec![D, D_FF], &mut off);
        block_flat = off - before;
    }
    push(&mut layout, "ln_f", vec![D], &mut off);
    ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
}

/// Deterministic `[a, b]` capture-site activations derived from the
/// chunk: distinct per site (`salt`), diagonally seeded so the Hessian
/// `2·X·Xᵀ` is comfortably positive definite for the solver methods.
fn site_vals(x: &[f32], a: usize, b: usize, salt: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; a * b];
    for t in 0..a {
        for f in 0..b {
            let v = x[(f * 31 + t * 7 + salt) % x.len()];
            let texture = ((f * 13 + t * 5 + salt) % 17) as f32 * 0.07;
            let diag = if t % b == f { 1.0 } else { 0.0 };
            out[t * b + f] = v + texture + diag;
        }
    }
    out
}

/// Artifact-free [`ChunkOps`]: `embed` reads only unpruned params (the
/// embedding, like the real embed pass), `forward` folds a digest of
/// the block's **current** weights into the chunk — so `begin` +
/// `reforward(0..k)` replayed over a restored state reproduces the
/// spill of an uninterrupted run bit-for-bit.
struct SynthOps {
    blocks: usize,
}

impl ChunkOps for SynthOps {
    fn n_blocks(&self) -> usize {
        self.blocks
    }
    fn n_chunks(&self) -> usize {
        CHUNKS
    }
    fn tokens_per_chunk(&self) -> usize {
        A
    }
    fn site_dims(&self) -> [usize; 4] {
        [D, D, D, D_FF]
    }
    fn embed(&mut self, state: &ModelState, ch: usize) -> Result<Vec<f32>> {
        let emb = state.get_mat("emb")?;
        Ok((0..A * D)
            .map(|i| emb.data[(i * 3 + ch * 11) % emb.data.len()] + ch as f32 * 0.125)
            .collect())
    }
    fn forward(&mut self, state: &ModelState, l: usize, x: &[f32]) -> Result<ChunkForward> {
        ensure!(x.len() == A * D, "bad chunk shape: {}", x.len());
        let digest = crc64_f32s(state.block_slice(l)?);
        let y: Vec<f32> = x
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let k = ((digest >> (8 * (i % 8))) & 0xFF) as f32 / 255.0;
                0.5 * v + 0.25 * k + 0.01
            })
            .collect();
        Ok(ChunkForward {
            y,
            sites: [
                site_vals(x, A, D, 1),
                site_vals(x, A, D, 2),
                site_vals(x, A, D, 3),
                site_vals(x, A, D_FF, 4),
            ],
        })
    }
}

// ------------------------------------------------------------------
// harness helpers

fn spec(pattern: Pattern) -> PruneSpec {
    PruneSpec {
        method: Method::Thanos,
        pattern,
        opts: PruneOpts { block_size: 4, ..Default::default() },
        backend: Backend::Rust,
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("thanos-schaos-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

fn robust_opts(jpath: &Path, resume: bool, mem_budget: Option<u64>) -> RobustOpts {
    RobustOpts { journal: Some(jpath.to_path_buf()), resume, mem_budget }
}

/// One journaled run over a fresh state; `mem_budget: None` is the
/// all-in-RAM mode every streamed run must match bitwise.
fn streamed_run(
    mm: &ModelManifest,
    sp: &PruneSpec,
    jpath: &Path,
    resume: bool,
    mem_budget: Option<u64>,
) -> Result<(Vec<u32>, PruneReport)> {
    let mut state = ModelState::init(mm, SEED);
    let mut pipe = StreamingPipeline::new(
        SynthOps { blocks: mm.config.n_layers },
        StreamOpts::new(mem_budget, jpath.with_extension("spill.thsc")),
    );
    let report = run_pruning(&mut state, &mut pipe, sp, &robust_opts(jpath, resume, mem_budget))?;
    Ok((bits(&state.flat), report))
}

/// Uninterrupted all-in-RAM reference: final weight bits + the bytes of
/// the progress checkpoint it leaves behind.
fn reference(mm: &ModelManifest, sp: &PruneSpec, jpath: &Path) -> (Vec<u32>, Vec<u8>) {
    faults::clear();
    let (b, _) = streamed_run(mm, sp, jpath, false, None).expect("reference run");
    let ckpt = std::fs::read(progress_ckpt_path(jpath)).unwrap();
    (b, ckpt)
}

/// Install `schedule`, run streamed until it kills the walk (panic or
/// error), clear faults, resume from the journal, and return the
/// resumed final bits + checkpoint bytes + resume report.
fn kill_then_resume(
    mm: &ModelManifest,
    sp: &PruneSpec,
    jpath: &Path,
    schedule: &str,
) -> (Vec<u32>, Vec<u8>, PruneReport) {
    let _ = std::fs::remove_file(jpath);
    let _ = std::fs::remove_file(progress_ckpt_path(jpath));
    faults::install(faults::parse_schedule(schedule).unwrap());
    let crashed = catch_unwind(AssertUnwindSafe(|| {
        streamed_run(mm, sp, jpath, false, Some(BUDGET)).map(|_| ())
    }));
    assert!(
        !matches!(crashed, Ok(Ok(()))),
        "schedule '{schedule}' did not interrupt the run"
    );
    faults::clear();
    let (got_bits, report) = streamed_run(mm, sp, jpath, true, Some(BUDGET))
        .unwrap_or_else(|e| panic!("resume after '{schedule}' failed: {e:#}"));
    let ckpt = std::fs::read(progress_ckpt_path(jpath)).unwrap();
    (got_bits, ckpt, report)
}

// ------------------------------------------------------------------
// streamed == in-RAM, across patterns and threading

#[test]
fn streamed_matches_in_ram_across_patterns_and_threading() {
    let _g = LOCK.lock().unwrap();
    faults::clear();
    let mm = micro_manifest();
    let dir = tmpdir("modes");
    let patterns =
        [Pattern::Unstructured { p: 0.5 }, Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 }];
    for (pi, pattern) in patterns.into_iter().enumerate() {
        let sp = spec(pattern);
        let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join(format!("ref{pi}.journal")));
        for serial in [false, true] {
            let jpath = dir.join(format!("p{pi}-s{serial}.journal"));
            let run = || streamed_run(&mm, &sp, &jpath, false, Some(BUDGET)).unwrap();
            let (got_bits, _) = if serial { thanos::engine::with_serial(run) } else { run() };
            assert_eq!(got_bits, ref_bits, "{pattern:?} serial={serial}: weights diverge");
            assert_eq!(
                std::fs::read(progress_ckpt_path(&jpath)).unwrap(),
                ref_ckpt,
                "{pattern:?} serial={serial}: checkpoint bytes diverge"
            );
        }
    }
}

// ------------------------------------------------------------------
// kill at every streaming fault site, serial and parallel

#[test]
fn kill_at_every_stream_site_then_resume_is_bitwise_identical() {
    let _g = LOCK.lock().unwrap();
    // under THANOS_CHAOS_ARTIFACTS (CI), also record a Chrome trace of
    // the matrix so the hessian.accum / pipeline.wait spans land there
    let artifacts = std::env::var("THANOS_CHAOS_ARTIFACTS").ok();
    if artifacts.is_some() {
        thanos::trace::set_enabled(true);
    }
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("matrix");
    let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join("ref.journal"));
    let jpath = dir.join("kill.journal");

    // nth=1 kills before any block commits (fresh restart); the later
    // hit lands inside block 1, after block 0's record — a true resume.
    // Per block the streamed walk probes: stream.read 2×(4 at open + 1
    // per chunk), stream.verify 2×(1 + 1 per chunk), and prefetch /
    // admit / stage once per chunk per stage.
    let later: &[(&str, usize)] = &[
        ("stream.read", 20),
        ("stream.verify", 12),
        ("stream.prefetch", 10),
        ("governor.admit", 10),
        ("pipeline.stage", 10),
    ];
    let mut schedules: Vec<String> = Vec::new();
    for (site, nth) in later {
        schedules.push(format!("{site}:1=panic"));
        schedules.push(format!("{site}:{nth}=panic"));
    }

    let mut total_resumed = 0u64;
    for serial in [false, true] {
        for schedule in &schedules {
            let run = || kill_then_resume(&mm, &sp, &jpath, schedule);
            let (got_bits, got_ckpt, report) =
                if serial { thanos::engine::with_serial(run) } else { run() };
            assert_eq!(
                got_bits, ref_bits,
                "serial={serial} '{schedule}': final weights diverge"
            );
            assert_eq!(
                got_ckpt, ref_ckpt,
                "serial={serial} '{schedule}': checkpoint bytes diverge"
            );
            total_resumed += report.resumed_layers;
        }
    }
    assert!(
        total_resumed > 0,
        "no schedule exercised a true resume (all restarted from scratch)"
    );

    if let Some(out) = artifacts {
        let out = PathBuf::from(out);
        std::fs::create_dir_all(&out).unwrap();
        std::fs::copy(&jpath, out.join("stream-chaos.journal")).unwrap();
        std::fs::copy(progress_ckpt_path(&jpath), out.join("stream-chaos.journal.ckpt")).unwrap();
        thanos::trace::export_to(&out.join("stream-chaos-trace.json")).unwrap();
        thanos::trace::set_enabled(false);
    }
}

// ------------------------------------------------------------------
// transient errors are absorbed by the retry ladder

#[test]
fn transient_stream_faults_are_retried_and_leave_no_trace_in_the_output() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("transient");
    let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join("ref.journal"));

    let jpath = dir.join("transient.journal");
    let _ = std::fs::remove_file(&jpath);
    faults::install(
        faults::parse_schedule(
            "stream.read:2=err;stream.verify:2=err;stream.prefetch:1=err;\
             governor.admit:2=err;pipeline.stage:3=err",
        )
        .unwrap(),
    );
    let (got_bits, report) = streamed_run(&mm, &sp, &jpath, false, Some(BUDGET)).unwrap();
    faults::clear();
    assert_eq!(report.faults_injected, 5, "all five scheduled faults should fire");
    assert!(report.retries >= 5, "each transient fault costs at least one retry");
    assert_eq!(got_bits, ref_bits, "retries must not change the result");
    assert_eq!(std::fs::read(progress_ckpt_path(&jpath)).unwrap(), ref_ckpt);
}

// ------------------------------------------------------------------
// a true process kill (skips every Drop), via subprocess re-exec

/// Runs only in the spawned child: streamed prune with an `exit` fault
/// armed, so the process dies mid-pipeline with no unwinding and no
/// `Drop` cleanup (the spill container survives as-is on disk).
#[test]
fn stream_chaos_child_worker() {
    let Ok(jpath) = std::env::var(CHILD_ENV) else { return };
    let schedule = std::env::var("THANOS_STREAM_CHILD_FAULTS").unwrap();
    faults::install(faults::parse_schedule(&schedule).unwrap());
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let _ = streamed_run(&mm, &sp, Path::new(&jpath), false, Some(BUDGET));
    // the armed exit should have killed the process before this line
    std::process::exit(0);
}

#[test]
fn a_real_process_kill_mid_stream_resumes_bitwise_identical() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("kill");
    let (ref_bits, ref_ckpt) = reference(&mm, &sp, &dir.join("ref.journal"));
    let jpath = dir.join("child.journal");
    let _ = std::fs::remove_file(&jpath);
    let _ = std::fs::remove_file(progress_ckpt_path(&jpath));

    let exe = std::env::current_exe().unwrap();
    let status = std::process::Command::new(&exe)
        .args(["stream_chaos_child_worker", "--exact", "--nocapture", "--test-threads=1"])
        .env(CHILD_ENV, &jpath)
        // the 10th prefetch lands inside block 1, after block 0 committed
        .env("THANOS_STREAM_CHILD_FAULTS", "stream.prefetch:10=exit(43)")
        .status()
        .unwrap();
    assert_eq!(status.code(), Some(43), "child should die at the injected exit");

    faults::clear();
    let (got_bits, report) = streamed_run(&mm, &sp, &jpath, true, Some(BUDGET)).unwrap();
    assert!(report.resumed_layers > 0, "the kill landed after a block committed");
    assert_eq!(got_bits, ref_bits, "weights diverge after a process kill");
    assert_eq!(
        std::fs::read(progress_ckpt_path(&jpath)).unwrap(),
        ref_ckpt,
        "checkpoint bytes diverge after a process kill"
    );
}

// ------------------------------------------------------------------
// container fuzzing (mirrors ckpt_corruption.rs for the spill format)

#[test]
fn chunk_table_bit_flips_and_truncations_are_rejected() {
    let _g = LOCK.lock().unwrap();
    faults::clear();
    let dir = tmpdir("fuzz");
    let p = dir.join("fuzz.thsc");
    let mut w = ChunkWriter::create(&p).unwrap();
    w.write_chunk_f32s(&[1.0, -2.5, 3.75]).unwrap();
    w.write_chunk_f32s(&[0.0, f32::NAN]).unwrap();
    w.finish().unwrap();
    let img = std::fs::read(&p).unwrap();

    let loads = |bytes: &[u8]| -> bool {
        std::fs::write(&p, bytes).unwrap();
        let mut r = match ChunkReader::open(&p) {
            Ok(r) => r,
            Err(_) => return false,
        };
        (0..r.n_chunks()).all(|i| r.read_chunk(i).is_ok())
    };
    assert!(loads(&img), "pristine container must load");

    // every bit of the chunk table + footer flipped → rejected
    let table_start = img.len() - 20 - 2 * 16;
    let mut work = img.clone();
    for i in table_start..img.len() {
        for bit in 0..8 {
            work[i] ^= 1 << bit;
            assert!(!loads(&work), "table/footer bit {bit} of byte {i} accepted");
            work[i] ^= 1 << bit;
        }
    }
    // payload corruption too (per-chunk CRC)
    work[9] ^= 0x10;
    assert!(!loads(&work), "payload corruption accepted");
    work[9] ^= 0x10;
    assert_eq!(work, img);
    // every truncation → rejected
    for len in 0..img.len() {
        assert!(!loads(&img[..len]), "truncation to {len} bytes accepted");
    }
}

// ------------------------------------------------------------------
// governor backpressure + fire-once registry accounting

#[test]
fn governor_keeps_in_flight_bytes_under_the_budget() {
    let _g = LOCK.lock().unwrap();
    faults::clear();
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("governor");
    let jpath = dir.join("governor.journal");
    let mut state = ModelState::init(&mm, SEED);
    let mut pipe = StreamingPipeline::new(
        SynthOps { blocks: mm.config.n_layers },
        StreamOpts::new(Some(BUDGET), jpath.with_extension("spill.thsc")),
    );
    run_pruning(&mut state, &mut pipe, &sp, &robust_opts(&jpath, false, Some(BUDGET))).unwrap();
    let g = pipe.governor();
    assert!(g.peak_bytes() > 0, "streamed mode must admit chunks");
    assert!(
        g.peak_bytes() <= BUDGET,
        "peak in-flight bytes {} exceed the {BUDGET}-byte budget",
        g.peak_bytes()
    );
    // every chunk admitted once per pipeline stage: blocks × 2 stages
    assert_eq!(g.admitted(), (mm.config.n_layers * 2 * CHUNKS) as u64);
}

/// Every streaming site round-trips through the `THANOS_FAULTS`
/// grammar with every action kind — so the chaos schedules above (and
/// CI's env-driven ones) can name any of them.
#[test]
fn the_fault_grammar_covers_every_stream_site_and_action() {
    let actions = ["err", "panic", "exit", "exit(43)", "trunc(8)"];
    let spec: Vec<String> = STREAM_SITES
        .iter()
        .zip(actions)
        .enumerate()
        .map(|(i, (site, action))| format!("{site}:{}={action}", i + 1))
        .collect();
    let sched = faults::parse_schedule(&spec.join(";")).unwrap();
    assert_eq!(sched.len(), STREAM_SITES.len());
    for (i, site) in STREAM_SITES.iter().enumerate() {
        assert!(
            sched.contains_key(&(site.to_string(), (i + 1) as u64)),
            "'{site}' missing from the parsed schedule"
        );
    }
    let at = |site: &str, nth: u64| sched.get(&(site.to_string(), nth)).copied();
    assert_eq!(at("stream.read", 1), Some(faults::Action::Err));
    assert_eq!(at("stream.verify", 2), Some(faults::Action::Panic));
    assert_eq!(at("stream.prefetch", 3), Some(faults::Action::Exit(101)));
    assert_eq!(at("governor.admit", 4), Some(faults::Action::Exit(43)));
    assert_eq!(at("pipeline.stage", 5), Some(faults::Action::Trunc(8)));
}

#[test]
fn two_runs_in_one_process_do_not_double_count_faults() {
    let _g = LOCK.lock().unwrap();
    let mm = micro_manifest();
    let sp = spec(Pattern::Unstructured { p: 0.5 });
    let dir = tmpdir("twice");

    // one transient fault armed: it fires in run 1 and is consumed
    // (fire-once), so run 2's per-run delta must be zero even though
    // both runs re-register every site
    faults::install(faults::parse_schedule("stream.prefetch:1=err").unwrap());
    let (bits1, r1) = streamed_run(&mm, &sp, &dir.join("a.journal"), false, Some(BUDGET)).unwrap();
    let (bits2, r2) = streamed_run(&mm, &sp, &dir.join("b.journal"), false, Some(BUDGET)).unwrap();
    faults::clear();
    assert_eq!(r1.faults_injected, 1, "the armed fault fires once, in run 1");
    assert_eq!(r2.faults_injected, 0, "run 2 must not re-count run 1's fault");
    assert_eq!(bits1, bits2, "a retried transient must not change the output");

    // site registration is idempotent across runs
    for site in STREAM_SITES {
        assert!(!faults::register_site(site), "'{site}' was dropped from the registry");
    }
}
