//! Fig. 1 — perplexity vs sparsity ratio.
//!
//! (a) unstructured pruning (the paper uses OPT-125M): Wanda /
//!     SparseGPT / Thanos over p ∈ {0.1 … 0.7};
//! (b) structured pruning (paper: LLaMA-3 8B): the same methods plus
//!     Thanos α = 0.1 over p ∈ {0.1 … 0.4}.
//!
//! Here both run on the trained `tiny` checkpoint (DESIGN.md
//! §Substitutions). Expected shape: (a) methods cluster, magnitude
//! diverges at high p; (b) Thanos clearly below SparseGPT below Wanda,
//! α=0.1 best — the paper's headline figure.

mod common;
use common::*;
use thanos::coordinator::Backend;
use thanos::harness::{ensure_trained, experiment_corpus, run_cell};
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;

fn main() {
    let model = env_str("THANOS_MODEL", "tiny");
    let steps = env_usize("THANOS_STEPS", 300);
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP fig1 bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let (state, _) = ensure_trained(&rt, &model, steps, 2e-3, 1234).expect("checkpoint");
    let corpus = experiment_corpus(&state.config);
    let dense = thanos::eval::perplexity(&rt, &state, &corpus.eval).unwrap();
    let opts = PruneOpts::default();
    let mut csv = Csv::new("fig1_ppl_vs_sparsity");
    let header = "panel,method,p,ppl";
    println!("== Fig. 1a: unstructured PPL vs sparsity ({model}, dense {dense:.3}) ==");
    println!(
        "  {:<12}{}",
        "p",
        [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]
            .iter()
            .map(|p| format!("{p:>9}"))
            .collect::<String>()
    );
    for method in [Method::Magnitude, Method::Wanda, Method::SparseGpt, Method::Thanos] {
        let mut line = format!("  {:<12}", method.name());
        for &p in &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7] {
            let (cell, _) = run_cell(
                &rt,
                &state,
                &corpus,
                method,
                Pattern::Unstructured { p },
                &opts,
                Backend::Rust,
                None,
            )
            .unwrap();
            line.push_str(&format!("{:>9.2}", cell.ppl));
            csv.row(header, &format!("a,{},{p},{:.4}", method.name(), cell.ppl));
        }
        println!("{line}");
    }

    println!("\n== Fig. 1b: structured PPL vs sparsity ==");
    println!(
        "  {:<16}{}",
        "p",
        [0.1, 0.2, 0.3, 0.4]
            .iter()
            .map(|p| format!("{p:>10}"))
            .collect::<String>()
    );
    let series: Vec<(String, Method, f64)> = vec![
        ("Wanda".into(), Method::Wanda, 0.0),
        ("SparseGPT".into(), Method::SparseGpt, 0.0),
        ("Thanos a=0".into(), Method::Thanos, 0.0),
        ("Thanos a=0.1".into(), Method::Thanos, 0.1),
    ];
    for (label, method, alpha) in &series {
        let mut line = format!("  {label:<16}");
        for &p in &[0.1, 0.2, 0.3, 0.4] {
            let (cell, _) = run_cell(
                &rt,
                &state,
                &corpus,
                *method,
                Pattern::Structured { p, alpha: *alpha },
                &opts,
                Backend::Rust,
                None,
            )
            .unwrap();
            line.push_str(&format!("{:>10.2}", cell.ppl));
            csv.row(header, &format!("b,{label},{p},{:.4}", cell.ppl));
        }
        println!("{line}");
    }
    println!("\nexpected shape: (a) update methods track each other, Magnitude");
    println!("diverges at high p; (b) Thanos < SparseGPT < Wanda, α=0.1 best.");
    println!("wrote bench_results/fig1_ppl_vs_sparsity.csv");
}
