//! Bounded-memory streaming prune trajectory (DESIGN.md §Streaming):
//! the same synthetic-`ChunkOps` pruning walk end-to-end in streamed
//! mode (spill container + governor + two-stage pipeline, under a byte
//! budget of a few chunks) and in the all-in-RAM reference mode, at a
//! TinyLlama-shaped-but-reduced configuration. Records wall time, the
//! process peak RSS (`VmHWM`), the governor's in-flight high-water
//! mark, and a CRC-64 of the final weights — so the trajectory file
//! itself witnesses that streaming changed memory, not math.
//!
//! `THANOS_STREAM_BENCH_MODE=streamed|inram|both` (default `both`)
//! selects the runs. `VmHWM` is a process-lifetime high-water mark, so
//! `both` runs streamed **first** and a single process can only bound
//! the in-RAM peak from below; CI's chaos-smoke job runs each mode in
//! its own process and gates on the recorded numbers instead.
//!
//! Results merge into `BENCH_prune_stream.json` (schema
//! thanos-prune-stream-bench/v1, keys `prune_stream/<shape>/<mode>`;
//! `THANOS_STREAM_BENCH_OUT` override).
//!
//! ```bash
//! cargo bench --bench prune_stream                      # full shape
//! THANOS_BENCH_QUICK=1 cargo bench --bench prune_stream # CI smoke
//! ```

mod common;
use common::*;

use anyhow::{ensure, Result};
use thanos::config::ModelConfig;
use thanos::coordinator::{
    run_pruning, Backend, ChunkForward, ChunkOps, PruneSpec, RobustOpts, StreamOpts,
    StreamingPipeline,
};
use thanos::model::ModelState;
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::robust::crc64_f32s;
use thanos::runtime::{ModelManifest, ParamEntry};

#[derive(Clone, Copy)]
struct Shape {
    label: &'static str,
    d_model: usize,
    d_ff: usize,
    blocks: usize,
    chunks: usize,
    /// token rows per calibration chunk
    a: usize,
}

/// A transformer manifest at the bench shape (same layout the serving
/// bench and the chaos harnesses build).
fn manifest(s: &Shape) -> ModelManifest {
    let cfg = ModelConfig {
        name: "stream-bench".into(),
        vocab: 16,
        d_model: s.d_model,
        n_layers: s.blocks,
        n_heads: 4,
        d_ff: s.d_ff,
        seq_len: 4,
    };
    let mut layout = Vec::new();
    let mut off = 0usize;
    let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
        let numel: usize = shape.iter().product();
        layout.push(ParamEntry { name: name.into(), offset: *off, shape });
        *off += numel;
    };
    push(&mut layout, "emb", vec![16, s.d_model], &mut off);
    push(&mut layout, "pos", vec![4, s.d_model], &mut off);
    let mut block_flat = 0;
    for l in 0..cfg.n_layers {
        let before = off;
        push(&mut layout, &format!("blocks.{l}.ln1"), vec![s.d_model], &mut off);
        for w in ["wq", "wk", "wv", "wo"] {
            push(&mut layout, &format!("blocks.{l}.{w}"), vec![s.d_model, s.d_model], &mut off);
        }
        push(&mut layout, &format!("blocks.{l}.ln2"), vec![s.d_model], &mut off);
        push(&mut layout, &format!("blocks.{l}.w1"), vec![s.d_ff, s.d_model], &mut off);
        push(&mut layout, &format!("blocks.{l}.w2"), vec![s.d_model, s.d_ff], &mut off);
        block_flat = off - before;
    }
    push(&mut layout, "ln_f", vec![s.d_model], &mut off);
    ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
}

/// Synthetic per-chunk compute (no AOT executables in a bench): `embed`
/// reads the embedding, `forward` folds a digest of the block's current
/// weights into the activations, and the capture sites are diagonally
/// seeded so every Hessian is positive definite. Identical math in
/// streamed and in-RAM mode — any weight-CRC mismatch between the two
/// recorded entries is a streaming bug.
struct SynthOps {
    s: Shape,
}

fn site_vals(x: &[f32], a: usize, b: usize, salt: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; a * b];
    for t in 0..a {
        for f in 0..b {
            let v = x[(f * 31 + t * 7 + salt) % x.len()];
            let texture = ((f * 13 + t * 5 + salt) % 17) as f32 * 0.07;
            let diag = if t % b == f { 1.0 } else { 0.0 };
            out[t * b + f] = v + texture + diag;
        }
    }
    out
}

impl ChunkOps for SynthOps {
    fn n_blocks(&self) -> usize {
        self.s.blocks
    }
    fn n_chunks(&self) -> usize {
        self.s.chunks
    }
    fn tokens_per_chunk(&self) -> usize {
        self.s.a
    }
    fn site_dims(&self) -> [usize; 4] {
        [self.s.d_model, self.s.d_model, self.s.d_model, self.s.d_ff]
    }
    fn embed(&mut self, state: &ModelState, ch: usize) -> Result<Vec<f32>> {
        let emb = state.get_mat("emb")?;
        let n = self.s.a * self.s.d_model;
        Ok((0..n)
            .map(|i| emb.data[(i * 3 + ch * 11) % emb.data.len()] + ch as f32 * 0.125)
            .collect())
    }
    fn forward(&mut self, state: &ModelState, l: usize, x: &[f32]) -> Result<ChunkForward> {
        ensure!(x.len() == self.s.a * self.s.d_model, "bad chunk shape: {}", x.len());
        let digest = crc64_f32s(state.block_slice(l)?);
        let y: Vec<f32> = x
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let k = ((digest >> (8 * (i % 8))) & 0xFF) as f32 / 255.0;
                0.5 * v + 0.25 * k + 0.01
            })
            .collect();
        Ok(ChunkForward {
            y,
            sites: [
                site_vals(x, self.s.a, self.s.d_model, 1),
                site_vals(x, self.s.a, self.s.d_model, 2),
                site_vals(x, self.s.a, self.s.d_model, 3),
                site_vals(x, self.s.a, self.s.d_ff, 4),
            ],
        })
    }
}

fn peak_rss_bytes() -> u64 {
    // Linux VmHWM (peak resident set, kB); 0 elsewhere — the field is
    // recorded as-is so non-Linux trajectory entries are visibly inert.
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else { return 0 };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb = rest.trim().trim_end_matches("kB").trim().parse::<u64>().unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// One full `run_pruning` walk at `shape`; `budget` Some = streamed.
fn run_mode(shape: &Shape, budget: Option<u64>, out: &mut BenchJson) -> u64 {
    let mode = if budget.is_some() { "streamed" } else { "inram" };
    let mm = manifest(shape);
    let mut state = ModelState::init(&mm, 41);
    let spill = std::env::temp_dir()
        .join(format!("thanos-stream-bench-{}-{mode}.thsc", std::process::id()));
    let mut pipe = StreamingPipeline::new(SynthOps { s: *shape }, StreamOpts::new(budget, spill));
    let spec = PruneSpec {
        method: Method::Thanos,
        pattern: Pattern::Unstructured { p: 0.5 },
        opts: PruneOpts { block_size: 32, ..Default::default() },
        backend: Backend::Rust,
    };
    let robust = RobustOpts { journal: None, resume: false, mem_budget: budget };

    let (report, wall) = time_s(|| {
        run_pruning(&mut state, &mut pipe, &spec, &robust).expect("pruning run")
    });
    let crc = crc64_f32s(&state.flat);
    let chunk_bytes = (shape.a * shape.d_model * 4) as u64;
    let (gov_peak, admitted) = (pipe.governor().peak_bytes(), pipe.governor().admitted());
    if let Some(b) = budget {
        assert!(
            gov_peak <= b,
            "governor peak {gov_peak} exceeds the {b}-byte budget"
        );
    }

    let key = format!("prune_stream/{}/{mode}", shape.label);
    out.record(
        &key,
        vec![
            ("wall_s", BenchJson::num(wall)),
            ("peak_rss_bytes", BenchJson::num(peak_rss_bytes() as f64)),
            ("governor_peak_bytes", BenchJson::num(gov_peak as f64)),
            ("admitted_chunks", BenchJson::num(admitted as f64)),
            ("mem_budget_bytes", BenchJson::num(budget.unwrap_or(0) as f64)),
            ("chunk_bytes", BenchJson::num(chunk_bytes as f64)),
            ("chunks", BenchJson::num(shape.chunks as f64)),
            ("blocks", BenchJson::num(shape.blocks as f64)),
            ("d_model", BenchJson::num(shape.d_model as f64)),
            ("d_ff", BenchJson::num(shape.d_ff as f64)),
            ("tokens_per_chunk", BenchJson::num(shape.a as f64)),
            ("weights_crc64", BenchJson::text(&format!("{crc:016x}"))),
            ("prune_secs", BenchJson::num(report.prune_secs)),
            ("capture_secs", BenchJson::num(report.capture_secs)),
            ("hessian_secs", BenchJson::num(report.hessian_secs)),
        ],
    );
    println!(
        "{key}: wall {wall:.2}s  rss {:.1} MiB  governor peak {gov_peak} B  crc {crc:016x}",
        peak_rss_bytes() as f64 / (1024.0 * 1024.0)
    );
    crc
}

fn main() {
    thanos::trace::init_from_env();
    let quick = quick_mode();
    // TinyLlama proportions (d_ff ≈ 2.75·d_model, 22 blocks) reduced to
    // CPU scale; quick is CI-sized.
    let shape = if quick {
        Shape { label: "quick", d_model: 32, d_ff: 88, blocks: 2, chunks: 8, a: 64 }
    } else {
        Shape { label: "tinyllama-r16", d_model: 128, d_ff: 352, blocks: 6, chunks: 24, a: 256 }
    };
    let chunk_bytes = (shape.a * shape.d_model * 4) as u64;
    // four chunks of headroom: capacity 2 queued + 1 in hand + 1 consumed
    let budget = 4 * chunk_bytes;

    let mode = env_str("THANOS_STREAM_BENCH_MODE", "both");
    let mut out = BenchJson::open_named(
        "BENCH_prune_stream.json",
        "thanos-prune-stream-bench/v1",
        "THANOS_STREAM_BENCH_OUT",
    );

    let mut crcs = Vec::new();
    if mode == "streamed" || mode == "both" {
        crcs.push(run_mode(&shape, Some(budget), &mut out));
    }
    if mode == "inram" || mode == "both" {
        crcs.push(run_mode(&shape, None, &mut out));
    }
    if crcs.len() == 2 {
        assert_eq!(
            crcs[0], crcs[1],
            "streamed and in-RAM pruning diverged — streaming changed the math"
        );
        println!("streamed == in-RAM (crc {:016x})", crcs[0]);
    }

    out.save();
    match thanos::trace::export() {
        Ok(Some(p)) => println!("trace written to {}", p.display()),
        Ok(None) => {}
        Err(e) => panic!("trace export failed: {e:#}"),
    }
}
