//! Table 5 / §5.4 — Thanos block-size ablation: perplexity of the
//! pruned TinyLlama-analogue across B ∈ {8 … 512} for unstructured
//! 50%, 4:8 and 2:4 sparsity.
//!
//! Paper finding to reproduce: unstructured perplexity is nearly flat
//! in B, while the n:m patterns improve with larger blocks (B=512 for
//! n:m in the paper's main experiments).

mod common;
use common::*;
use thanos::coordinator::Backend;
use thanos::harness::{ensure_trained, experiment_corpus, run_cell};
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;

fn main() {
    let model = env_str("THANOS_MODEL", "tiny");
    let steps = env_usize("THANOS_STEPS", 300);
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP table5 bench: {e:#}");
            return;
        }
    };
    let (state, _) = ensure_trained(&rt, &model, steps, 2e-3, 1234).expect("checkpoint");
    let corpus = experiment_corpus(&state.config);
    let dense = thanos::eval::perplexity(&rt, &state, &corpus.eval).unwrap();
    let mut csv = Csv::new("table5_blocksize");
    let header = "pattern,block_size,ppl";

    let blocks = [8usize, 32, 64, 128, 256, 512];
    println!("== Table 5: Thanos blocksize ablation ({model}, dense ppl {dense:.3}) ==\n");
    println!(
        "  {:<22}{}",
        "pattern \\ B",
        blocks.iter().map(|b| format!("{b:>9}")).collect::<String>()
    );
    for (label, pattern) in [
        ("unstructured 50%", Pattern::Unstructured { p: 0.5 }),
        ("4:8", Pattern::SemiStructured { n: 4, m: 8, alpha: 0.0 }),
        ("2:4", Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 }),
    ] {
        let mut line = format!("  {label:<22}");
        for &bsize in &blocks {
            let opts = PruneOpts { block_size: bsize, ..Default::default() };
            let (cell, _) = run_cell(
                &rt,
                &state,
                &corpus,
                Method::Thanos,
                pattern,
                &opts,
                Backend::Rust,
                None,
            )
            .unwrap();
            line.push_str(&format!("{:>9.2}", cell.ppl));
            csv.row(header, &format!("{label},{bsize},{:.4}", cell.ppl));
        }
        println!("{line}");
    }
    println!("\nexpected shape: unstructured row ~flat; n:m rows improve (fall)");
    println!("as B grows — paper Table 5.");
    println!("wrote bench_results/table5_blocksize.csv");
}
