//! Serving throughput/latency trajectory (DESIGN.md §Serving): a real
//! `Server` on a loopback socket, hammered by {1, 8, 32} concurrent
//! clients against a compressed model at 50% unstructured and 2:4
//! sparsity. Measures end-to-end request throughput plus the server's
//! own queue+compute latency distribution (p50/p99 from the admission
//! histogram — the same numbers `ServeSnapshot` exports to metrics).
//!
//! Every client bitwise-checks its first answer against the unbatched
//! `forward_batch` oracle, so a kernel or batching regression fails the
//! bench before it pollutes the trajectory file.
//!
//! Results merge into `BENCH_serving.json` (schema
//! thanos-serving-bench/v1, keys `serving/<pattern>/c<clients>`;
//! `THANOS_SERVE_BENCH_OUT` override). CI's `serve-smoke` job runs this
//! in quick mode with tracing on and uploads both artifacts.
//!
//! ```bash
//! cargo bench --bench serving                      # full shapes
//! THANOS_BENCH_QUICK=1 cargo bench --bench serving # CI smoke
//! ```

mod common;
use common::*;

use std::time::Instant;

use thanos::config::ModelConfig;
use thanos::linalg::Mat;
use thanos::model::ModelState;
use thanos::pruning::{magnitude, Pattern};
use thanos::runtime::{ModelManifest, ParamEntry};
use thanos::serve::{ServeClient, ServeOptions, Server};
use thanos::sparse::SparseModel;

/// A serving-sized transformer manifest: the prunable chain is
/// d_model → d_model, so every request carries d_model floats.
fn manifest(d_model: usize, n_layers: usize, d_ff: usize) -> ModelManifest {
    let cfg = ModelConfig {
        name: "serve-bench".into(),
        vocab: 16,
        d_model,
        n_layers,
        n_heads: 4,
        d_ff,
        seq_len: 4,
    };
    let mut layout = Vec::new();
    let mut off = 0usize;
    let push = |layout: &mut Vec<ParamEntry>, name: &str, shape: Vec<usize>, off: &mut usize| {
        let numel: usize = shape.iter().product();
        layout.push(ParamEntry { name: name.into(), offset: *off, shape });
        *off += numel;
    };
    push(&mut layout, "emb", vec![16, d_model], &mut off);
    push(&mut layout, "pos", vec![4, d_model], &mut off);
    let mut block_flat = 0;
    for l in 0..cfg.n_layers {
        let before = off;
        push(&mut layout, &format!("blocks.{l}.ln1"), vec![d_model], &mut off);
        for w in ["wq", "wk", "wv", "wo"] {
            push(&mut layout, &format!("blocks.{l}.{w}"), vec![d_model, d_model], &mut off);
        }
        push(&mut layout, &format!("blocks.{l}.ln2"), vec![d_model], &mut off);
        push(&mut layout, &format!("blocks.{l}.w1"), vec![d_ff, d_model], &mut off);
        push(&mut layout, &format!("blocks.{l}.w2"), vec![d_model, d_ff], &mut off);
        block_flat = off - before;
    }
    push(&mut layout, "ln_f", vec![d_model], &mut off);
    ModelManifest { config: cfg, flat_size: off, block_flat_size: block_flat, layout }
}

/// Magnitude-prune every prunable layer to `pat` and compress.
fn compressed_model(pat: &Pattern, d_model: usize, n_layers: usize, d_ff: usize) -> SparseModel {
    let mm = manifest(d_model, n_layers, d_ff);
    let mut st = ModelState::init(&mm, 7);
    for l in 0..mm.config.n_layers {
        for name in st.prunable_layers(l) {
            let w = st.get_mat(&name).expect("get layer");
            let pruned = match pat {
                Pattern::Unstructured { p } => magnitude::unstructured(&w, *p),
                Pattern::SemiStructured { n, m, .. } => magnitude::semi_structured(&w, *n, *m),
                Pattern::Structured { p, .. } => magnitude::structured(&w, *p),
            };
            st.set_mat(&name, &pruned.w).expect("set layer");
        }
    }
    SparseModel::compress_state(&st, pat).expect("compress")
}

fn probe(d_model: usize, tag: usize) -> Vec<f32> {
    (0..d_model).map(|i| ((tag * 1009 + i) as f32 * 0.11).sin()).collect()
}

fn main() {
    thanos::trace::init_from_env();
    let quick = quick_mode();
    let (d_model, d_ff, n_layers) = if quick { (32, 64, 2) } else { (64, 256, 2) };
    let reqs_per_client = env_usize("THANOS_SERVE_BENCH_REQS", if quick { 40 } else { 200 });

    let mut out = BenchJson::open_named(
        "BENCH_serving.json",
        "thanos-serving-bench/v1",
        "THANOS_SERVE_BENCH_OUT",
    );
    let metrics = thanos::metrics::Metrics::new();

    let patterns = [
        ("unstructured50", Pattern::Unstructured { p: 0.5 }),
        ("2to4", Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 }),
    ];
    for (pkey, pat) in &patterns {
        let sm = compressed_model(pat, d_model, n_layers, d_ff);
        for &clients in &[1usize, 8, 32] {
            let opts = ServeOptions {
                max_batch: 32,
                batch_window_ms: 2,
                default_deadline_ms: 60_000,
                ..Default::default()
            };
            let server =
                Server::start(sm.clone(), format!("bench-{pkey}"), opts).expect("server start");
            let addr = server.local_addr();

            let t0 = Instant::now();
            let handles: Vec<_> = (0..clients)
                .map(|t| {
                    let sm = sm.clone();
                    std::thread::spawn(move || {
                        let mut c = ServeClient::connect(addr).expect("connect");
                        for r in 0..reqs_per_client {
                            let input = probe(d_model, t * 7919 + r);
                            let resp = c.infer(&input, 0).expect("infer");
                            assert!(resp.is_ok(), "bench request failed: {}", resp.reason);
                            if r == 0 {
                                // Bitwise oracle: batched serving must
                                // equal the unbatched forward pass.
                                let x = Mat::from_vec(input.len(), 1, input.clone());
                                let want = sm.forward_batch(&x).expect("oracle").data;
                                for (a, b) in resp.output.iter().zip(&want) {
                                    assert_eq!(
                                        a.to_bits(),
                                        b.to_bits(),
                                        "served answer diverged from the oracle"
                                    );
                                }
                            }
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("client thread");
            }
            let wall = t0.elapsed().as_secs_f64();

            let snap = server.snapshot();
            let total = (clients * reqs_per_client) as f64;
            assert_eq!(snap.completed, total as u64, "every request must complete");
            metrics.record_serve(&format!("serve.{pkey}.c{clients}"), &snap);
            let key = format!("serving/{pkey}/c{clients}");
            out.record(
                &key,
                vec![
                    ("requests", BenchJson::num(total)),
                    ("throughput_rps", BenchJson::num(total / wall)),
                    ("p50_ms", BenchJson::num(snap.p50_ms)),
                    ("p99_ms", BenchJson::num(snap.p99_ms)),
                    ("batches", BenchJson::num(snap.batches as f64)),
                    ("shed", BenchJson::num(snap.shed as f64)),
                    ("wall_s", BenchJson::num(wall)),
                ],
            );
            println!(
                "{key}: {:.0} rps  p50 {:.3} ms  p99 {:.3} ms  ({} reqs, {} batches)",
                total / wall,
                snap.p50_ms,
                snap.p99_ms,
                total as u64,
                snap.batches
            );
        }
    }

    out.save();
    println!("{}", metrics.report());
    match thanos::trace::export() {
        Ok(Some(p)) => println!("trace written to {}", p.display()),
        Ok(None) => {}
        Err(e) => panic!("trace export failed: {e:#}"),
    }
}
