//! Table 1 — complexity comparison of the pruning methods.
//!
//! The paper's Table 1 states asymptotic complexities (Magnitude
//! O(c²log c), Wanda O(c²log c), SparseGPT O(c³), Thanos
//! O(c⁴/B + c²B²) unstructured / O(c³) structured). This bench
//! regenerates the table empirically: wall-clock at square shapes
//! c = b ∈ {128..1024} plus the fitted growth exponent between
//! consecutive doublings, and prints the feature matrix (optimal block
//! updates / weight update / calibration data) alongside.
//!
//! Thanos unstructured is measured in BOTH inverse modes: the
//! paper-faithful per-block inversion (the Table-1 O(c⁴/B) row) and the
//! suffix-factor fast path this library defaults to (O(c³)).

mod common;
use common::*;
use thanos::linalg::Mat;
use thanos::pruning::{self, CalibStats, PruneOpts};

type Variant = (&'static str, Box<dyn Fn(&Mat, &CalibStats)>);

fn main() {
    let max_n = env_usize("THANOS_T1_MAX", 1024);
    let sizes: Vec<usize> = [128usize, 256, 512, 1024]
        .into_iter()
        .filter(|&n| n <= max_n)
        .collect();
    let mut csv = Csv::new("table1_complexity");
    println!("== Table 1: empirical method complexity (c = b, unstructured 50%) ==\n");
    println!("feature matrix (paper Table 1):");
    println!("  method      optimal-block-updates  weight-update  calibration-data");
    println!("  Magnitude   no                     no             no");
    println!("  Wanda       no                     no             yes");
    println!("  SparseGPT   no                     yes            yes");
    println!("  Thanos      yes                    yes            yes\n");

    let header = "method,n,secs";
    println!(
        "  {:<26}{}",
        "method \\ c=b",
        sizes.iter().map(|n| format!("{n:>16}")).collect::<String>()
    );

    let variants: Vec<Variant> = vec![
        ("Magnitude", Box::new(|w, _s| {
            pruning::magnitude::unstructured(w, 0.5);
        })),
        ("Wanda", Box::new(|w, s| {
            pruning::wanda::unstructured(w, s, 0.5);
        })),
        ("SparseGPT", Box::new(|w, s| {
            let o = PruneOpts { block_size: 128, ..Default::default() };
            pruning::sparsegpt::unstructured(w, s, 0.5, &o).unwrap();
        })),
        ("Thanos (paper O(c4/B))", Box::new(|w, s| {
            let o = PruneOpts {
                block_size: 128,
                paper_faithful_inverse: true,
                ..Default::default()
            };
            pruning::thanos::unstructured(w, s, 0.5, &o).unwrap();
        })),
        ("Thanos (fast, O(c3))", Box::new(|w, s| {
            let o = PruneOpts { block_size: 128, ..Default::default() };
            pruning::thanos::unstructured(w, s, 0.5, &o).unwrap();
        })),
        ("Thanos structured", Box::new(|w, s| {
            let o = PruneOpts::default();
            pruning::thanos::structured(w, s, 0.3, 0.1, &o).unwrap();
        })),
        ("SparseGPT structured", Box::new(|w, s| {
            let o = PruneOpts::default();
            pruning::sparsegpt::structured(w, s, 0.3, &o).unwrap();
        })),
    ];

    for (name, f) in &variants {
        // paper-faithful O(c^4/B) explodes past 512 — cap it
        let cap = if name.contains("paper") { 512 } else { usize::MAX };
        let mut line = format!("  {name:<26}");
        let mut prev: Option<f64> = None;
        for &n in &sizes {
            if n > cap {
                line.push_str(&format!("{:>16}", "-"));
                continue;
            }
            let (w, stats, _) = bench_layer(n, n, n + 64, 42);
            let (_, secs) = time_s(|| f(&w, &stats));
            csv.row(header, &format!("{name},{n},{secs:.4}"));
            let exp = prev
                .map(|p| format!(" (^{:.1})", (secs / p).log2()))
                .unwrap_or_default();
            line.push_str(&format!("{:>9.3}s{exp:<6}", secs));
            prev = Some(secs);
        }
        println!("{line}");
    }
    println!("\n(^k) = growth exponent vs previous size; expect ~2 for the metric");
    println!("methods, ~3 for SparseGPT/fast-Thanos, ~4 for paper-faithful Thanos.");
    println!("wrote bench_results/table1_complexity.csv");
}
