//! Shared helpers for the paper-table benches (`harness = false`
//! binaries — no criterion in the offline vendor set; plain
//! `std::time::Instant` with warm-up + CSV emission).

#![allow(dead_code)]

use std::io::Write;
use thanos::linalg::Mat;
use thanos::pruning::CalibStats;
use thanos::rng::Rng;

/// Correlated calibration layer setup at arbitrary shape — the same
/// generator the test-suite uses, sized for bench workloads.
pub fn bench_layer(c: usize, b: usize, a: usize, seed: u64) -> (Mat, CalibStats, Mat) {
    let mut r = Rng::new(seed);
    let w = Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
    let k = (b / 8).max(2);
    let factors = Mat::from_fn(k, a, |_, _| r.normal_f32(0.0, 1.0));
    let loading = Mat::from_fn(b, k, |_, _| r.normal_f32(0.0, 0.5));
    let mut x = thanos::linalg::gemm::matmul(&loading, &factors);
    for v in x.data.iter_mut() {
        *v += r.normal_f32(0.0, 0.5);
    }
    let stats = CalibStats::from_x(&x);
    (w, stats, x)
}

/// Time a closure (single shot — pruning runs are seconds-scale, so no
/// repetition harness is needed; determinism comes from fixed seeds).
pub fn time_s<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Append rows to `bench_results/<name>.csv` (header written once).
pub struct Csv {
    path: String,
    wrote_header: bool,
}

impl Csv {
    pub fn new(name: &str) -> Csv {
        std::fs::create_dir_all("bench_results").ok();
        let path = format!("bench_results/{name}.csv");
        std::fs::remove_file(&path).ok();
        Csv { path, wrote_header: false }
    }

    pub fn row(&mut self, header: &str, values: &str) {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .expect("open csv");
        if !self.wrote_header {
            writeln!(f, "{header}").unwrap();
            self.wrote_header = true;
        }
        writeln!(f, "{values}").unwrap();
    }
}

pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_str(name: &str, default: &str) -> String {
    std::env::var(name).unwrap_or_else(|_| default.to_string())
}

/// `THANOS_BENCH_QUICK=1`: CI-sized shapes for every bench that feeds
/// [`BenchJson`].
pub fn quick_mode() -> bool {
    env_str("THANOS_BENCH_QUICK", "0") == "1"
}

use thanos::jsonutil::{obj, Json};

/// Shared machine-readable perf-trajectory writer: benches merge their
/// measurements into one JSON file at the repo root, keyed by
/// `bench/shape/case`. Existing entries from other benches (and a
/// file-level `provenance` note, if the committed file carries one) are
/// preserved, so several benches each own a keyspace of the same file
/// and future PRs can diff like against like. The linalg benches share
/// `BENCH_linalg.json` ([`BenchJson::open`]); the end-to-end pruning
/// trajectory lives in `BENCH_pruning.json`
/// ([`BenchJson::open_named`]).
pub struct BenchJson {
    path: std::path::PathBuf,
    schema: String,
    provenance: Option<String>,
    entries: std::collections::BTreeMap<String, Json>,
}

impl BenchJson {
    pub fn open() -> BenchJson {
        BenchJson::open_named("BENCH_linalg.json", "thanos-linalg-bench/v1", "THANOS_BENCH_OUT")
    }

    /// Open (or create) the repo-root trajectory file `file_name` with
    /// the given schema tag; `env_override` names an env var holding an
    /// alternative output path.
    pub fn open_named(file_name: &str, schema: &str, env_override: &str) -> BenchJson {
        let path = std::env::var(env_override)
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|_| {
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(file_name)
            });
        let doc = Json::parse_file(&path).ok();
        let provenance = doc
            .as_ref()
            .and_then(|j| j.get_opt("provenance"))
            .and_then(|p| p.as_str().ok().map(str::to_string));
        let entries = doc
            .and_then(|j| j.get_opt("entries").cloned())
            .and_then(|e| match e {
                Json::Obj(m) => Some(m),
                _ => None,
            })
            .unwrap_or_default();
        BenchJson { path, schema: schema.to_string(), provenance, entries }
    }

    /// Record (or replace) one entry; `fields` become the entry object.
    /// Run context (`threads`, `quick`) is stamped per entry — entries
    /// from different runs coexist in one file, so a file-global stamp
    /// would mislabel retained entries.
    pub fn record(&mut self, key: &str, fields: Vec<(&str, Json)>) {
        let mut fields = fields;
        fields.push((
            "threads",
            Json::Num(thanos::linalg::gemm::num_threads() as f64),
        ));
        fields.push(("quick", Json::Bool(quick_mode())));
        self.entries.insert(key.to_string(), obj(fields));
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    /// v1 → v2 migration for thread-axis schemas: rows recorded without
    /// a `/t<threads>` key suffix (the v1 addressing) are re-keyed from
    /// their stamped per-entry `threads` field, so a v1 file loads into
    /// a v2 writer without colliding with (or shadowing) the new
    /// per-thread-count rows. Rows already carrying a `/t` suffix are
    /// left untouched, so v2 files round-trip unchanged.
    pub fn rekey_threads(&mut self, prefix: &str) {
        let keys: Vec<String> = self
            .entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        for k in keys {
            let last = k.rsplit('/').next().unwrap_or("");
            let suffixed = last.len() > 1
                && last.starts_with('t')
                && last[1..].chars().all(|c| c.is_ascii_digit());
            if suffixed {
                continue;
            }
            if let Some(entry) = self.entries.remove(&k) {
                let t = entry
                    .get_opt("threads")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(1.0) as usize;
                self.entries.insert(format!("{k}/t{t}"), entry);
            }
        }
    }

    pub fn text(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    /// Write the merged document (pretty-printed, stable key order).
    /// Atomic temp-file + rename, so an interrupted bench run never
    /// clobbers the previous results file with a torn one.
    pub fn save(&self) {
        let mut fields = vec![("schema", Json::Str(self.schema.clone()))];
        if let Some(p) = &self.provenance {
            fields.push(("provenance", Json::Str(p.clone())));
        }
        fields.push(("entries", Json::Obj(self.entries.clone())));
        let doc = obj(fields);
        let mut text = doc.to_string_pretty();
        text.push('\n');
        thanos::robust::write_atomic(&self.path, text.as_bytes()).expect("write bench json");
        println!("merged results into {}", self.path.display());
    }
}
