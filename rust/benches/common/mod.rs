//! Shared helpers for the paper-table benches (`harness = false`
//! binaries — no criterion in the offline vendor set; plain
//! `std::time::Instant` with warm-up + CSV emission).

#![allow(dead_code)]

use std::io::Write;
use thanos::linalg::Mat;
use thanos::pruning::CalibStats;
use thanos::rng::Rng;

/// Correlated calibration layer setup at arbitrary shape — the same
/// generator the test-suite uses, sized for bench workloads.
pub fn bench_layer(c: usize, b: usize, a: usize, seed: u64) -> (Mat, CalibStats, Mat) {
    let mut r = Rng::new(seed);
    let w = Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
    let k = (b / 8).max(2);
    let factors = Mat::from_fn(k, a, |_, _| r.normal_f32(0.0, 1.0));
    let loading = Mat::from_fn(b, k, |_, _| r.normal_f32(0.0, 0.5));
    let mut x = thanos::linalg::gemm::matmul(&loading, &factors);
    for v in x.data.iter_mut() {
        *v += r.normal_f32(0.0, 0.5);
    }
    let stats = CalibStats::from_x(&x);
    (w, stats, x)
}

/// Time a closure (single shot — pruning runs are seconds-scale, so no
/// repetition harness is needed; determinism comes from fixed seeds).
pub fn time_s<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Append rows to `bench_results/<name>.csv` (header written once).
pub struct Csv {
    path: String,
    wrote_header: bool,
}

impl Csv {
    pub fn new(name: &str) -> Csv {
        std::fs::create_dir_all("bench_results").ok();
        let path = format!("bench_results/{name}.csv");
        std::fs::remove_file(&path).ok();
        Csv { path, wrote_header: false }
    }

    pub fn row(&mut self, header: &str, values: &str) {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .expect("open csv");
        if !self.wrote_header {
            writeln!(f, "{header}").unwrap();
            self.wrote_header = true;
        }
        writeln!(f, "{values}").unwrap();
    }
}

pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_str(name: &str, default: &str) -> String {
    std::env::var(name).unwrap_or_else(|_| default.to_string())
}
