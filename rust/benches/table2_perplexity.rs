//! Table 2 — held-out perplexity of pruned models across the full
//! sparsity grid: unstructured 50%, structured 30% (α = 0 / 0.1),
//! 4:8 and 2:4 (α = 0 / 0.1), for Magnitude / Wanda / SparseGPT /
//! Thanos, on every trained model preset in the artifacts.
//!
//! The paper's LLaMA-2/3 columns map to the tiny/small/med presets
//! (DESIGN.md §Substitutions); the claim reproduced is the method
//! *ranking* per pattern, not absolute perplexities.

mod common;
use common::*;
use thanos::coordinator::Backend;
use thanos::harness::{ensure_trained, experiment_corpus, format_table, run_cell};
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;

fn main() {
    let models = env_str("THANOS_BENCH_MODELS", "tiny");
    let steps = env_usize("THANOS_STEPS", 300);
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP table2 bench: {e:#}");
            return;
        }
    };
    let mut csv = Csv::new("table2_perplexity");
    let header = "model,method,pattern,ppl,sparsity,secs";
    let opts = PruneOpts::default();

    for model in models.split(',') {
        let (state, _) = match ensure_trained(&rt, model, steps, 2e-3, 1234) {
            Ok(x) => x,
            Err(e) => {
                println!("SKIP model {model}: {e:#}");
                continue;
            }
        };
        let corpus = experiment_corpus(&state.config);
        let dense = thanos::eval::perplexity(&rt, &state, &corpus.eval).unwrap();
        println!("\n== Table 2 ({model}): dense ppl {dense:.3} ==");
        let patterns = [
            Pattern::Unstructured { p: 0.5 },
            Pattern::Structured { p: 0.3, alpha: 0.0 },
            Pattern::Structured { p: 0.3, alpha: 0.1 },
            Pattern::SemiStructured { n: 4, m: 8, alpha: 0.0 },
            Pattern::SemiStructured { n: 4, m: 8, alpha: 0.1 },
            Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
            Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 },
        ];
        let mut cells = Vec::new();
        for pattern in patterns {
            let alpha_cell = matches!(
                pattern,
                Pattern::Structured { alpha, .. } | Pattern::SemiStructured { alpha, .. }
                if alpha > 0.0
            );
            for method in Method::ALL {
                if alpha_cell && method != Method::Thanos {
                    continue; // α is a Thanos-only mechanism in the paper
                }
                let (cell, _) = run_cell(
                    &rt, &state, &corpus, method, pattern, &opts, Backend::Rust, None,
                )
                .unwrap();
                csv.row(
                    header,
                    &format!(
                        "{model},{},{},{:.4},{:.4},{:.2}",
                        method.name(),
                        pattern.label().replace(',', ";"),
                        cell.ppl,
                        cell.sparsity,
                        cell.prune_secs
                    ),
                );
                cells.push(cell);
            }
        }
        print!("{}", format_table(dense, &cells));

        // ranking checks per pattern family (the Table-2 shape)
        let ppl = |m: Method, label: &str| {
            cells
                .iter()
                .find(|c| c.method == m && c.pattern.label() == label)
                .map(|c| c.ppl)
                .unwrap_or(f64::NAN)
        };
        let s_th = ppl(Method::Thanos, "structured 30% (α=0)");
        let s_sg = ppl(Method::SparseGpt, "structured 30% (α=0)");
        let s_wa = ppl(Method::Wanda, "structured 30% (α=0)");
        let s_a1 = ppl(Method::Thanos, "structured 30% (α=0.1)");
        println!(
            "\n  struct-30 ranking: Thanos(α=.1) {s_a1:.2} | Thanos {s_th:.2} | SparseGPT {s_sg:.2} | Wanda {s_wa:.2}  -> {}",
            if s_th <= s_sg && s_sg <= s_wa { "matches paper" } else { "DEVIATES" }
        );
    }
    println!("\nwrote bench_results/table2_perplexity.csv");
}
