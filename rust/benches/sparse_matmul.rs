//! Measured dense-vs-sparse matmul: the wall-clock counterpart of the
//! modeled n:m figure (DESIGN.md §Sparse — the Fig. 9-adjacent claim
//! that Thanos's hardware-friendly patterns convert into real
//! throughput once the weights are stored compressed).
//!
//! For each layer shape × batch width the sweep prunes one matrix to
//! 50/60/70% unstructured (→ CSR), 2:4 and 4:8 (→ NmPacked) and 50/70%
//! structured (→ DenseCompact), then times
//!
//! * the dense GEMM on the unpruned matrix (the serving baseline),
//! * the dense GEMM on the pruned matrix (zero-skipping),
//! * the compressed-format kernel,
//!
//! and reports actual compressed bytes. Every case is cross-validated
//! against `linalg::gemm` within 1e-5 relative error — a divergence
//! fails the process, which is what makes the CI quick run a format
//! regression gate.
//!
//! ```bash
//! cargo bench --bench sparse_matmul                 # full sweep
//! THANOS_SPARSE_QUICK=1 cargo bench --bench sparse_matmul   # CI smoke
//! ```

mod common;
use common::*;
use thanos::sparse::bench::{sweep, SweepRow};

fn main() {
    // THANOS_SPARSE_QUICK=1 (historical) or THANOS_BENCH_QUICK=1
    let quick = env_str("THANOS_SPARSE_QUICK", "0") == "1" || quick_mode();
    let shapes = thanos::sparse::bench::default_shapes(quick);
    let batches = thanos::sparse::bench::default_batches(quick);

    let mut bj = BenchJson::open();
    let mut csv = Csv::new("sparse_matmul");
    let mut worst_err = 0.0f64;
    let mut nm24_matvec: Vec<SweepRow> = Vec::new();
    println!("== measured dense vs sparse matmul (CPU kernels) ==");
    println!("(dense = unpruned GEMM; bytes = compressed vs dense f32)\n");
    for &(c, b) in shapes {
        for &batch in batches {
            println!("-- {c}x{b}, batch {batch} --");
            let rows = sweep(c, b, batch, 0xBEC).expect("sweep failed");
            for row in rows {
                println!("{}", row.pretty());
                csv.row(SweepRow::csv_header(), &row.csv());
                bj.record(
                    &format!("sparse_matmul/{c}x{b}/batch{batch}/{}", row.case),
                    vec![
                        ("sparsity", BenchJson::num(row.sparsity)),
                        ("dense_ms", BenchJson::num(row.dense_ms)),
                        ("pruned_dense_ms", BenchJson::num(row.pruned_dense_ms)),
                        ("sparse_ms", BenchJson::num(row.sparse_ms)),
                        ("speedup_vs_dense", BenchJson::num(row.speedup_vs_dense())),
                        ("bytes_sparse", BenchJson::num(row.bytes_sparse as f64)),
                        ("bytes_dense", BenchJson::num(row.bytes_dense as f64)),
                        ("max_rel_err", BenchJson::num(row.max_rel_err)),
                    ],
                );
                worst_err = worst_err.max(row.max_rel_err);
                if row.case == "nm(2:4)" && batch == 1 {
                    nm24_matvec.push(row);
                }
            }
            println!();
        }
    }
    bj.save();

    for row in &nm24_matvec {
        println!(
            "2:4 matvec {}x{}: measured {:.2}x vs dense (modeled GPU figure, secondary: {:.2}x)",
            row.rows,
            row.cols,
            row.speedup_vs_dense(),
            thanos::pruning::nm::modeled_speedup(2, 4),
        );
    }
    println!("wrote bench_results/sparse_matmul.csv");

    // regression gate: the formats must agree with the dense GEMM
    assert!(
        worst_err <= 1e-5,
        "sparse kernel diverged from linalg::gemm: max rel err {worst_err:.3e}"
    );
    println!("kernel cross-validation vs gemm: OK (max rel err {worst_err:.1e})");
}
