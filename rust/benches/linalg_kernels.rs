//! Per-kernel perf trajectory: the packed register-tiled core
//! (DESIGN.md §Perf-L3) vs the seed loop nests, in ONE process via the
//! `THANOS_LINALG_NAIVE` runtime switch, at default engine threads.
//!
//! Measures GEMM f32, SYRK f64 (`xxt_f64`), blocked Cholesky, blocked
//! TRSM (`upper_tri_solve_many`) and an end-to-end Thanos layer prune
//! (the Fig. 9 unit of work), then merges everything into
//! `BENCH_linalg.json` at the repo root through the shared
//! `benches/common` writer.
//!
//! Every kernel is cross-validated old-path vs new-path; divergence
//! beyond summation-reorder tolerances fails the process — this is the
//! CI `bench-smoke` regression gate.
//!
//! ```bash
//! cargo bench --bench linalg_kernels                     # full shapes
//! THANOS_BENCH_QUICK=1 cargo bench --bench linalg_kernels  # CI smoke
//! ```

mod common;
use common::*;
use thanos::linalg::chol::{cholesky_in_place, upper_tri_solve_many};
use thanos::linalg::gemm::{matmul, xxt_f64};
use thanos::linalg::kernel;
use thanos::linalg::{Mat, MatF64};
use thanos::pruning::{self, PruneOpts};
use thanos::rng::Rng;
use thanos::sparse::bench::best_of;

/// Max |entry| of an f64 matrix (the rel-error scale).
fn scale_f64(m: &MatF64) -> f64 {
    m.data.iter().fold(1.0f64, |s, &v| s.max(v.abs()))
}

fn scale_f32(m: &Mat) -> f64 {
    m.data.iter().fold(1.0f32, |s, &v| s.max(v.abs())) as f64
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 2 } else { 3 };
    let mut bj = BenchJson::open();
    let mut worst_f32 = 0.0f64;
    let mut worst_f64 = 0.0f64;
    println!(
        "== linalg kernels: packed register-tiled core vs seed paths ({} threads) ==\n",
        thanos::linalg::gemm::num_threads()
    );

    // ---- GEMM f32 ----------------------------------------------------
    let gemm_shapes: &[(usize, usize, usize)] = if quick {
        &[(192, 192, 192), (256, 256, 256)]
    } else {
        &[(512, 512, 512), (1024, 1024, 1024)]
    };
    for &(m, k, n) in gemm_shapes {
        let mut r = Rng::new((m * 31 + n) as u64);
        let a = Mat::from_fn(m, k, |_, _| r.normal_f32(0.0, 1.0));
        let b = Mat::from_fn(k, n, |_, _| r.normal_f32(0.0, 1.0));
        kernel::set_naive_mode(true);
        let c_naive = matmul(&a, &b);
        let secs_naive = best_of(reps, || {
            matmul(&a, &b);
        });
        kernel::set_naive_mode(false);
        let c_packed = matmul(&a, &b);
        let secs_packed = best_of(reps, || {
            matmul(&a, &b);
        });
        let rel = c_packed.max_abs_diff(&c_naive) as f64 / scale_f32(&c_naive);
        worst_f32 = worst_f32.max(rel);
        let flops = 2.0 * (m * k * n) as f64;
        let speedup = secs_naive / secs_packed.max(1e-12);
        println!(
            "gemm_f32  {m}x{k}x{n}: naive {:>7.2} GF/s  packed {:>7.2} GF/s  {speedup:>5.2}x  rel {rel:.1e}",
            flops / secs_naive / 1e9,
            flops / secs_packed / 1e9,
        );
        bj.record(
            &format!("gemm_f32/{m}x{k}x{n}"),
            vec![
                ("secs_naive", BenchJson::num(secs_naive)),
                ("secs_packed", BenchJson::num(secs_packed)),
                ("gflops_naive", BenchJson::num(flops / secs_naive / 1e9)),
                ("gflops_packed", BenchJson::num(flops / secs_packed / 1e9)),
                ("speedup", BenchJson::num(speedup)),
                ("rel_err", BenchJson::num(rel)),
            ],
        );
    }

    // ---- SYRK f64 (xxt_f64) ------------------------------------------
    let syrk_shapes: &[(usize, usize)] = if quick {
        &[(192, 384)]
    } else {
        &[(512, 1024), (1024, 2048)]
    };
    for &(b, a_len) in syrk_shapes {
        let mut r = Rng::new((b * 7 + a_len) as u64);
        let x = Mat::from_fn(b, a_len, |_, _| r.normal_f32(0.0, 1.0));
        kernel::set_naive_mode(true);
        let h_naive = xxt_f64(&x);
        let secs_naive = best_of(reps, || {
            xxt_f64(&x);
        });
        kernel::set_naive_mode(false);
        let h_packed = xxt_f64(&x);
        let secs_packed = best_of(reps, || {
            xxt_f64(&x);
        });
        let rel = h_packed.max_abs_diff(&h_naive) / scale_f64(&h_naive);
        worst_f64 = worst_f64.max(rel);
        let flops = 2.0 * (b * b * a_len) as f64;
        let speedup = secs_naive / secs_packed.max(1e-12);
        println!(
            "syrk_f64  b={b} a={a_len}: naive {:>7.2} GF/s  packed {:>7.2} GF/s  {speedup:>5.2}x  rel {rel:.1e}",
            flops / secs_naive / 1e9,
            flops / secs_packed / 1e9,
        );
        bj.record(
            &format!("syrk_f64/b{b}xa{a_len}"),
            vec![
                ("secs_naive", BenchJson::num(secs_naive)),
                ("secs_packed", BenchJson::num(secs_packed)),
                ("gflops_naive", BenchJson::num(flops / secs_naive / 1e9)),
                ("gflops_packed", BenchJson::num(flops / secs_packed / 1e9)),
                ("speedup", BenchJson::num(speedup)),
                ("rel_err", BenchJson::num(rel)),
            ],
        );
    }

    // ---- blocked Cholesky f64 ----------------------------------------
    let chol_sizes: &[usize] = if quick { &[192] } else { &[512, 1024] };
    for &n in chol_sizes {
        let mut r = Rng::new(n as u64 + 3);
        let x = Mat::from_fn(n, n + 8, |_, _| r.normal_f32(0.0, 1.0));
        let mut h = xxt_f64(&x);
        thanos::linalg::chol::damp_hessian(&mut h, 0.01);
        let time_chol = |naive: bool| -> (MatF64, f64) {
            kernel::set_naive_mode(naive);
            let mut best = f64::INFINITY;
            let mut out = h.clone();
            cholesky_in_place(&mut out).expect("SPD by construction"); // warm
            for _ in 0..reps {
                let mut m = h.clone();
                let t0 = std::time::Instant::now();
                cholesky_in_place(&mut m).expect("SPD by construction");
                best = best.min(t0.elapsed().as_secs_f64());
                out = m;
            }
            (out, best)
        };
        let (l_naive, secs_naive) = time_chol(true);
        let (l_packed, secs_packed) = time_chol(false);
        let rel = l_packed.max_abs_diff(&l_naive) / scale_f64(&l_naive);
        worst_f64 = worst_f64.max(rel);
        let speedup = secs_naive / secs_packed.max(1e-12);
        println!(
            "chol_f64  n={n}: naive {secs_naive:>7.4}s  blocked {secs_packed:>7.4}s  {speedup:>5.2}x  rel {rel:.1e}"
        );
        bj.record(
            &format!("chol_f64/n{n}"),
            vec![
                ("secs_naive", BenchJson::num(secs_naive)),
                ("secs_packed", BenchJson::num(secs_packed)),
                ("speedup", BenchJson::num(speedup)),
                ("rel_err", BenchJson::num(rel)),
            ],
        );
    }

    // ---- blocked TRSM f64 (upper_tri_solve_many) ---------------------
    let trsm_sizes: &[(usize, usize)] = if quick { &[(128, 128)] } else { &[(512, 512)] };
    for &(s, n) in trsm_sizes {
        let mut r = Rng::new((s + n) as u64);
        // diagonally dominant upper triangle: both paths stay accurate
        let off = 1.0 / s as f64;
        let u = MatF64::from_fn(s, s, |i, j| {
            if i > j {
                0.0
            } else if i == j {
                2.0
            } else {
                off * r.normal()
            }
        });
        let rhs = MatF64::from_fn(s, n, |_, _| r.normal());
        kernel::set_naive_mode(true);
        let x_naive = upper_tri_solve_many(&u, &rhs);
        let secs_naive = best_of(reps, || {
            upper_tri_solve_many(&u, &rhs);
        });
        kernel::set_naive_mode(false);
        let x_packed = upper_tri_solve_many(&u, &rhs);
        let secs_packed = best_of(reps, || {
            upper_tri_solve_many(&u, &rhs);
        });
        let rel = x_packed.max_abs_diff(&x_naive) / scale_f64(&x_naive);
        worst_f64 = worst_f64.max(rel);
        let speedup = secs_naive / secs_packed.max(1e-12);
        println!(
            "trsm_f64  s={s} n={n}: naive {secs_naive:>7.4}s  blocked {secs_packed:>7.4}s  {speedup:>5.2}x  rel {rel:.1e}"
        );
        bj.record(
            &format!("trsm_f64/s{s}xn{n}"),
            vec![
                ("secs_naive", BenchJson::num(secs_naive)),
                ("secs_packed", BenchJson::num(secs_packed)),
                ("speedup", BenchJson::num(speedup)),
                ("rel_err", BenchJson::num(rel)),
            ],
        );
    }

    // ---- end-to-end: one Fig. 9 layer prune --------------------------
    let d = if quick { 96 } else { 256 };
    let (w, stats, _x) = bench_layer(d, d, (d / 2).max(64), 7);
    let opts = PruneOpts { block_size: 64, ..Default::default() };
    kernel::set_naive_mode(true);
    pruning::thanos::unstructured(&w, &stats, 0.5, &opts).expect("prune (naive)");
    let secs_naive = best_of(1, || {
        pruning::thanos::unstructured(&w, &stats, 0.5, &opts).expect("prune (naive)");
    });
    kernel::set_naive_mode(false);
    pruning::thanos::unstructured(&w, &stats, 0.5, &opts).expect("prune (packed)");
    let secs_packed = best_of(1, || {
        pruning::thanos::unstructured(&w, &stats, 0.5, &opts).expect("prune (packed)");
    });
    let speedup = secs_naive / secs_packed.max(1e-12);
    println!(
        "fig9_e2e  d={d}: naive {secs_naive:>7.3}s  packed {secs_packed:>7.3}s  {speedup:>5.2}x (Thanos fast, unstr 50%)"
    );
    bj.record(
        &format!("fig9_e2e/d{d}"),
        vec![
            ("secs_naive", BenchJson::num(secs_naive)),
            ("secs_packed", BenchJson::num(secs_packed)),
            ("speedup", BenchJson::num(speedup)),
        ],
    );

    bj.save();

    // ---- regression gates (CI bench-smoke fails on divergence) -------
    assert!(
        worst_f32 <= 5e-5,
        "packed f32 kernel diverged from the seed path: rel {worst_f32:.3e}"
    );
    assert!(
        worst_f64 <= 1e-9,
        "packed f64 kernels diverged from the seed paths: rel {worst_f64:.3e}"
    );
    println!("\npacked-vs-naive cross-validation: OK (f32 {worst_f32:.1e}, f64 {worst_f64:.1e})");
}
