//! Table 3 + Appendix D (Tables 6–17) — zero-shot accuracy of pruned
//! models: per-task breakdown and the 7-task average, over the same
//! method × pattern grid as Table 2.
//!
//! Tasks are the seven synthetic LM-scored multiple-choice suites
//! (DESIGN.md §Substitutions maps them to WinoGrande/OBQA/BoolQ/PiQA/
//! HellaSwag/ARC-e/ARC-c); the readout — per-option log-likelihood
//! scoring with argmax — is exactly the EleutherAI-harness mechanism.

mod common;
use common::*;
use thanos::coordinator::{Backend, Coordinator, PruneSpec};
use thanos::data::ALL_TASKS;
use thanos::harness::{ensure_trained, experiment_corpus};
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;

fn main() {
    let model = env_str("THANOS_MODEL", "tiny");
    let steps = env_usize("THANOS_STEPS", 300);
    let n_inst = env_usize("THANOS_ZEROSHOT_N", 40);
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP table3 bench: {e:#}");
            return;
        }
    };
    let (state, _) = ensure_trained(&rt, &model, steps, 2e-3, 1234).expect("checkpoint");
    let corpus = experiment_corpus(&state.config);
    let mut csv = Csv::new("table3_zeroshot");
    let header = "method,pattern,task,accuracy";

    let grid: Vec<(Method, Pattern)> = {
        let mut g = Vec::new();
        for pattern in [
            Pattern::Unstructured { p: 0.5 },
            Pattern::Structured { p: 0.3, alpha: 0.0 },
            Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
            Pattern::SemiStructured { n: 4, m: 8, alpha: 0.0 },
        ] {
            for method in Method::ALL {
                g.push((method, pattern));
            }
        }
        g.push((Method::Thanos, Pattern::Structured { p: 0.3, alpha: 0.1 }));
        g.push((Method::Thanos, Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 }));
        g.push((Method::Thanos, Pattern::SemiStructured { n: 4, m: 8, alpha: 0.1 }));
        g
    };

    // header row: task names
    let tasks: Vec<&str> = ALL_TASKS.iter().map(|t| t.name()).collect();
    println!("== Table 3 / App. D: zero-shot accuracy ({model}, {n_inst} inst/task) ==\n");
    println!(
        "  {:<12}{:<22}{}{:>8}",
        "Method",
        "Sparsity",
        tasks.iter().map(|t| format!("{t:>14}")).collect::<String>(),
        "Avg"
    );

    // dense row
    let zs = thanos::eval::zero_shot_suite(&rt, &state, &corpus.grammar, n_inst, 1234).unwrap();
    let mut line = format!("  {:<12}{:<22}", "Dense", "0%");
    for (_, acc) in &zs {
        line.push_str(&format!("{:>13.1}%", acc * 100.0));
    }
    println!(
        "{line}{:>7.1}%",
        thanos::eval::zero_shot_average(&zs) * 100.0
    );

    for (method, pattern) in grid {
        let mut st = state.clone();
        let spec = PruneSpec {
            method,
            pattern,
            opts: PruneOpts::default(),
            backend: Backend::Rust,
        };
        Coordinator::new(&rt)
            .prune_model(&mut st, &corpus.calib, &spec)
            .unwrap();
        let zs = thanos::eval::zero_shot_suite(&rt, &st, &corpus.grammar, n_inst, 1234).unwrap();
        let mut line = format!("  {:<12}{:<22}", method.name(), pattern.label());
        for (t, acc) in &zs {
            line.push_str(&format!("{:>13.1}%", acc * 100.0));
            csv.row(
                header,
                &format!(
                    "{},{},{},{:.4}",
                    method.name(),
                    pattern.label().replace(',', ";"),
                    t.name(),
                    acc
                ),
            );
        }
        println!(
            "{line}{:>7.1}%",
            thanos::eval::zero_shot_average(&zs) * 100.0
        );
    }
    println!("\nexpected shape: averages track the Table-2 PPL ranking; Thanos");
    println!("leads structured/semi-structured, α=0.1 adds a further margin.");
    println!("wrote bench_results/table3_zeroshot.csv");
}
