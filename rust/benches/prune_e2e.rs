//! End-to-end prune-time trajectory (§Perf-L4): one full Thanos layer
//! prune per variant (unstructured / 2:4 / structured), measured on
//! THREE paths in one process —
//!
//! * `naive`  — `THANOS_LINALG_NAIVE` semantics: seed linalg kernels
//!   AND the per-row reference walk (the cross-check oracle);
//! * `perrow` — packed linalg core (§Perf-L3) with the pre-§Perf-L4
//!   walk: per-row scalar solves + axpy-chain applies, scalar eq. 13 Δ,
//!   O(c·b²) naive `row_losses` (`opts.panel_apply = false`);
//! * `panel`  — the Λ-panel walk: §H.1 padded batched solves,
//!   mixed-precision packed GEMM applies, GEMM Δ and GEMM `row_losses`
//!   (`opts.panel_apply = true`).
//!
//! **Divergence gate** (CI `bench-smoke` runs this in quick mode):
//! when the panel walk's mask is bitwise equal to the naive oracle's
//! (the measured norm — every committed entry records
//! `mask_mismatch_rows: 0`), weights must agree within 1e-5 of the
//! layer's weight scale (max |w| — the same max-scaled rel-err
//! convention as `BENCH_linalg.json`).
//! The one sanctioned exception is unstructured at the largest full
//! shape: there the global-residual selection's boundary gap is the
//! same order as the panel/per-row f32 rounding delta (measured
//! ~6e-6 vs ~9e-6 at c=3072, b=1024), and a single boundary-tie flip
//! cascades through `r_left` into later blocks — a property of the
//! walk, not a bug. That case falls back to an exact-sparsity +
//! reconstruction-quality gate, which a real kernel bug still trips
//! instantly.
//!
//! Results merge into `BENCH_pruning.json` (schema
//! thanos-prune-bench/v2: every key carries a `/t<threads>` suffix so
//! rows from different `THANOS_THREADS` runs coexist — CI runs this at
//! 1 and 4 threads; v1 rows are migrated on load via
//! `BenchJson::rekey_threads`; `THANOS_PRUNE_BENCH_OUT` override).
//! A `prune_e2e/select/...` keyspace records the §Perf-L5 selection
//! stage head-to-head (select_nth oracle vs threshold engine, bitwise
//! mask gate), so the "selection is no longer serial" claim is
//! measured at every thread count.
//!
//! ```bash
//! cargo bench --bench prune_e2e                      # full shapes
//! THANOS_BENCH_QUICK=1 cargo bench --bench prune_e2e # CI smoke
//! ```

mod common;
use common::*;
use thanos::linalg::kernel;
use thanos::linalg::Mat;
use thanos::pruning::metric::{smallest_r_mask_into_with_idx, wanda_metric_window_into};
use thanos::pruning::select::{smallest_r_mask_threshold_into, SelectScratch};
use thanos::pruning::{self, CalibStats, Method, Pattern, PruneOpts, Pruned};
use thanos::sparse::bench::best_of;

fn pattern_key(p: &Pattern) -> &'static str {
    match p {
        Pattern::Unstructured { .. } => "unstructured",
        Pattern::SemiStructured { .. } => "2to4",
        Pattern::Structured { .. } => "structured",
    }
}

fn run(w: &Mat, stats: &CalibStats, pat: Pattern, opts: &PruneOpts) -> Pruned {
    pruning::prune(Method::Thanos, w, stats, pat, opts).expect("prune")
}

/// Row-wise cross-check: (rows whose masks differ, worst weight rel
/// over the mask-agreeing rows).
fn cross_check(a: &Pruned, b: &Pruned, c: usize, cols: usize) -> (usize, f64) {
    let scale = b.w.data.iter().fold(1.0f32, |s, &v| s.max(v.abs())) as f64;
    let mut bad_rows = 0usize;
    let mut worst = 0.0f64;
    for i in 0..c {
        let (r0, r1) = (i * cols, (i + 1) * cols);
        if a.mask[r0..r1] != b.mask[r0..r1] {
            bad_rows += 1;
            continue;
        }
        for (x, y) in a.w.data[r0..r1].iter().zip(&b.w.data[r0..r1]) {
            let d = (x - y).abs() as f64 / scale;
            if d > worst {
                worst = d;
            }
        }
    }
    (bad_rows, worst)
}

/// Masked-cell count: the walk's sparsity target is deterministic, so
/// two tie-flipped (but healthy) prunes still agree here; incidental
/// exact zeros in kept cells are path-dependent and excluded.
fn masked(p: &Pruned) -> usize {
    p.mask.iter().filter(|&&m| m).count()
}

fn main() {
    // THANOS_TRACE=out.json traces the whole sweep (Chrome trace +
    // per-stage histogram rows in the bench JSON)
    thanos::trace::init_from_env();
    let quick = quick_mode();
    let reps = if quick { 1 } else { 2 };
    // (c, b, a): out-features, in-features, calibration width.
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(96, 128, 96), (192, 256, 128)]
    } else {
        &[(1024, 512, 256), (3072, 1024, 512)]
    };
    let block = 64;
    let patterns = [
        Pattern::Unstructured { p: 0.5 },
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 },
        Pattern::Structured { p: 0.3, alpha: 0.1 },
    ];
    let mut bj = BenchJson::open_named(
        "BENCH_pruning.json",
        "thanos-prune-bench/v2",
        "THANOS_PRUNE_BENCH_OUT",
    );
    // keep v1 rows loadable: migrate thread-less keys onto the v2 axis
    bj.rekey_threads("prune_e2e/");
    let threads = thanos::linalg::gemm::num_threads();
    println!("== prune e2e: naive / per-row(packed linalg) / Λ-panel ({threads} threads) ==\n");
    let largest = *shapes.last().unwrap();
    for &(c, b, a) in shapes {
        let (w, stats, x) = bench_layer(c, b, a, 0xE2E + (c + b) as u64);
        for pat in patterns {
            let key = pattern_key(&pat);
            let perrow_opts =
                PruneOpts { block_size: block, panel_apply: false, ..Default::default() };
            let panel_opts =
                PruneOpts { block_size: block, panel_apply: true, ..Default::default() };

            // naive oracle (seed kernels + per-row walk)
            kernel::set_naive_mode(true);
            let p_naive = run(&w, &stats, pat, &perrow_opts);
            let secs_naive = best_of(reps, || {
                run(&w, &stats, pat, &perrow_opts);
            });

            // packed linalg, per-row walk (the pre-§Perf-L4 baseline)
            kernel::set_naive_mode(false);
            let _warm = run(&w, &stats, pat, &perrow_opts);
            let secs_perrow = best_of(reps, || {
                run(&w, &stats, pat, &perrow_opts);
            });

            // Λ-panel walk
            let p_panel = run(&w, &stats, pat, &panel_opts);
            let secs_panel = best_of(reps, || {
                run(&w, &stats, pat, &panel_opts);
            });

            // divergence gate vs the naive oracle (see module docs)
            let (bad_rows, rel) = cross_check(&p_panel, &p_naive, c, b);
            if bad_rows == 0 {
                assert!(
                    rel <= 1e-5,
                    "{key} c{c}xb{b}: panel diverged from the naive oracle: rel {rel:.3e}"
                );
            } else {
                let tie_flip_possible = !quick
                    && (c, b, a) == largest
                    && matches!(pat, Pattern::Unstructured { .. });
                assert!(
                    tie_flip_possible,
                    "{key} c{c}xb{b}: {bad_rows} rows with diverged masks (only the largest \
                     unstructured full shape may boundary-tie flip)"
                );
                // boundary-tie fallback: the two walks are different
                // (equally valid) prunes — same exact sparsity, and
                // reconstruction quality must agree closely
                assert_eq!(masked(&p_panel), masked(&p_naive), "{key}: sparsity diverged");
                let lp = thanos::linalg::gemm::recon_loss(&p_panel.w, &w, &x);
                let ln = thanos::linalg::gemm::recon_loss(&p_naive.w, &w, &x);
                assert!(
                    (lp - ln).abs() <= 0.02 * ln.max(1e-12),
                    "{key}: quality diverged after tie flip: {lp} vs {ln}"
                );
            }

            let sp_naive = secs_naive / secs_panel.max(1e-12);
            let sp_perrow = secs_perrow / secs_panel.max(1e-12);
            println!(
                "{key:>12} c={c} b={b}: naive {secs_naive:>8.3}s  per-row {secs_perrow:>8.3}s  \
                 panel {secs_panel:>8.3}s  {sp_perrow:>5.2}x vs per-row  rel {rel:.1e}"
            );
            bj.record(
                &format!("prune_e2e/{key}/c{c}xb{b}/t{threads}"),
                vec![
                    ("secs_naive", BenchJson::num(secs_naive)),
                    ("secs_perrow", BenchJson::num(secs_perrow)),
                    ("secs_panel", BenchJson::num(secs_panel)),
                    ("speedup_vs_perrow", BenchJson::num(sp_perrow)),
                    ("speedup_vs_naive", BenchJson::num(sp_naive)),
                    ("rel_err_vs_naive", BenchJson::num(rel)),
                    ("mask_mismatch_rows", BenchJson::num(bad_rows as f64)),
                    ("block_size", BenchJson::num(block as f64)),
                ],
            );
            // perf gate, full mode only (quick/CI shapes are too small
            // to amortize packing — they gate correctness alone). The
            // 2:4 and structured walks ride the row_losses/Δ GEMMs to
            // large wins; the unstructured walk is selection/solve
            // bound (see DESIGN.md §Perf-L4), so it only gates against
            // regression.
            if !quick && (c, b, a) == largest {
                match pat {
                    // §Perf-L5: threshold select + interleaved/per-row
                    // solve dispatch made the unstructured walk
                    // compute-bound (C mirror measured ~1.6× on this
                    // ratio; gate with machine margin)
                    Pattern::Unstructured { .. } => assert!(
                        sp_perrow >= 1.2,
                        "{key} c{c}xb{b}: unstructured panel speedup {sp_perrow:.2}x < 1.2x"
                    ),
                    _ => assert!(
                        sp_perrow >= 2.0,
                        "{key} c{c}xb{b}: panel speedup {sp_perrow:.2}x < 2x over per-row"
                    ),
                }
            }
        }

        // §Perf-L5 selection-stage head-to-head on this shape: the
        // select_nth oracle vs the threshold engine over the full
        // residual window, masks gated bitwise. Emitted per thread
        // count, so the multi-threaded rows measure the stage that
        // used to be the walk's serial Amdahl cap.
        {
            let sel_reps = if quick { 2 } else { 3 };
            let mut metric = Vec::new();
            wanda_metric_window_into(&w, &stats, 0, b, &mut metric);
            // quick shapes sit below the engine's band floor (where the
            // public entry rightly dispatches to the oracle) — tile the
            // window up so the measured/gated path is the multi-band
            // engine at every shape
            while metric.len() < (1 << 18) {
                metric.extend_from_within(..);
            }
            let r = metric.len() / 2;
            let mut scratch = SelectScratch::new();
            let mut m_oracle = Vec::new();
            let mut m_thresh = Vec::new();
            let secs_oracle = best_of(sel_reps, || {
                smallest_r_mask_into_with_idx(&metric, r, &mut m_oracle, &mut scratch.idx);
            });
            let secs_thresh = best_of(sel_reps, || {
                smallest_r_mask_threshold_into(&metric, r, &mut m_thresh, &mut scratch);
            });
            assert_eq!(
                m_oracle, m_thresh,
                "c{c}xb{b}: threshold select diverged from the select_nth oracle"
            );
            let sp = secs_oracle / secs_thresh.max(1e-12);
            println!(
                "{:>12} c={c} b={b}: oracle {secs_oracle:>8.4}s  threshold {secs_thresh:>8.4}s  \
                 {sp:>5.2}x",
                "select"
            );
            bj.record(
                &format!("prune_e2e/select/c{c}xb{b}/t{threads}"),
                vec![
                    ("secs_oracle", BenchJson::num(secs_oracle)),
                    ("secs_threshold", BenchJson::num(secs_thresh)),
                    ("speedup", BenchJson::num(sp)),
                    ("r_frac", BenchJson::num(0.5)),
                    ("cells", BenchJson::num(metric.len() as f64)),
                ],
            );
        }
    }
    // traced stage breakdown: spans paired per worker, folded into
    // count/total plus latency quantiles from the log-bucket histogram
    if thanos::trace::enabled() {
        for st in thanos::trace::aggregate() {
            let q = |p: f64| st.hist.quantile(p).unwrap_or(0) as f64 / 1e3;
            bj.record(
                &format!("prune_e2e/stages/{}/t{threads}", st.name),
                vec![
                    ("count", BenchJson::num(st.count as f64)),
                    ("total_secs", BenchJson::num(st.total_secs())),
                    ("p50_us", BenchJson::num(q(0.5))),
                    ("p90_us", BenchJson::num(q(0.9))),
                    ("p99_us", BenchJson::num(q(0.99))),
                ],
            );
        }
    }
    bj.save();
    match thanos::trace::export() {
        Ok(Some(path)) => println!("trace written to {}", path.display()),
        Ok(None) => {}
        Err(e) => panic!("trace export failed: {e:#}"),
    }
    println!("\nnaive-path cross-check: OK");
}
