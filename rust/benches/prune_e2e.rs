//! End-to-end prune-time trajectory (§Perf-L4): one full Thanos layer
//! prune per variant (unstructured / 2:4 / structured), measured on
//! THREE paths in one process —
//!
//! * `naive`  — `THANOS_LINALG_NAIVE` semantics: seed linalg kernels
//!   AND the per-row reference walk (the cross-check oracle);
//! * `perrow` — packed linalg core (§Perf-L3) with the pre-§Perf-L4
//!   walk: per-row scalar solves + axpy-chain applies, scalar eq. 13 Δ,
//!   O(c·b²) naive `row_losses` (`opts.panel_apply = false`);
//! * `panel`  — the Λ-panel walk: §H.1 padded batched solves,
//!   mixed-precision packed GEMM applies, GEMM Δ and GEMM `row_losses`
//!   (`opts.panel_apply = true`).
//!
//! **Divergence gate** (CI `bench-smoke` runs this in quick mode):
//! when the panel walk's mask is bitwise equal to the naive oracle's
//! (the measured norm — every committed entry records
//! `mask_mismatch_rows: 0`), weights must agree within 1e-5 of the
//! layer's weight scale (max |w| — the same max-scaled rel-err
//! convention as `BENCH_linalg.json`).
//! The one sanctioned exception is unstructured at the largest full
//! shape: there the global-residual selection's boundary gap is the
//! same order as the panel/per-row f32 rounding delta (measured
//! ~6e-6 vs ~9e-6 at c=3072, b=1024), and a single boundary-tie flip
//! cascades through `r_left` into later blocks — a property of the
//! walk, not a bug. That case falls back to an exact-sparsity +
//! reconstruction-quality gate, which a real kernel bug still trips
//! instantly.
//!
//! Results merge into `BENCH_pruning.json` (schema
//! thanos-prune-bench/v1, `THANOS_PRUNE_BENCH_OUT` override).
//!
//! ```bash
//! cargo bench --bench prune_e2e                      # full shapes
//! THANOS_BENCH_QUICK=1 cargo bench --bench prune_e2e # CI smoke
//! ```

mod common;
use common::*;
use thanos::linalg::kernel;
use thanos::linalg::Mat;
use thanos::pruning::{self, CalibStats, Method, Pattern, PruneOpts, Pruned};
use thanos::sparse::bench::best_of;

fn pattern_key(p: &Pattern) -> &'static str {
    match p {
        Pattern::Unstructured { .. } => "unstructured",
        Pattern::SemiStructured { .. } => "2to4",
        Pattern::Structured { .. } => "structured",
    }
}

fn run(w: &Mat, stats: &CalibStats, pat: Pattern, opts: &PruneOpts) -> Pruned {
    pruning::prune(Method::Thanos, w, stats, pat, opts).expect("prune")
}

/// Row-wise cross-check: (rows whose masks differ, worst weight rel
/// over the mask-agreeing rows).
fn cross_check(a: &Pruned, b: &Pruned, c: usize, cols: usize) -> (usize, f64) {
    let scale = b.w.data.iter().fold(1.0f32, |s, &v| s.max(v.abs())) as f64;
    let mut bad_rows = 0usize;
    let mut worst = 0.0f64;
    for i in 0..c {
        let (r0, r1) = (i * cols, (i + 1) * cols);
        if a.mask[r0..r1] != b.mask[r0..r1] {
            bad_rows += 1;
            continue;
        }
        for (x, y) in a.w.data[r0..r1].iter().zip(&b.w.data[r0..r1]) {
            let d = (x - y).abs() as f64 / scale;
            if d > worst {
                worst = d;
            }
        }
    }
    (bad_rows, worst)
}

/// Masked-cell count: the walk's sparsity target is deterministic, so
/// two tie-flipped (but healthy) prunes still agree here; incidental
/// exact zeros in kept cells are path-dependent and excluded.
fn masked(p: &Pruned) -> usize {
    p.mask.iter().filter(|&&m| m).count()
}

fn main() {
    let quick = quick_mode();
    let reps = if quick { 1 } else { 2 };
    // (c, b, a): out-features, in-features, calibration width.
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(96, 128, 96), (192, 256, 128)]
    } else {
        &[(1024, 512, 256), (3072, 1024, 512)]
    };
    let block = 64;
    let patterns = [
        Pattern::Unstructured { p: 0.5 },
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 },
        Pattern::Structured { p: 0.3, alpha: 0.1 },
    ];
    let mut bj = BenchJson::open_named(
        "BENCH_pruning.json",
        "thanos-prune-bench/v1",
        "THANOS_PRUNE_BENCH_OUT",
    );
    println!(
        "== prune e2e: naive / per-row(packed linalg) / Λ-panel ({} threads) ==\n",
        thanos::linalg::gemm::num_threads()
    );
    let largest = *shapes.last().unwrap();
    for &(c, b, a) in shapes {
        let (w, stats, x) = bench_layer(c, b, a, 0xE2E + (c + b) as u64);
        for pat in patterns {
            let key = pattern_key(&pat);
            let perrow_opts =
                PruneOpts { block_size: block, panel_apply: false, ..Default::default() };
            let panel_opts =
                PruneOpts { block_size: block, panel_apply: true, ..Default::default() };

            // naive oracle (seed kernels + per-row walk)
            kernel::set_naive_mode(true);
            let p_naive = run(&w, &stats, pat, &perrow_opts);
            let secs_naive = best_of(reps, || {
                run(&w, &stats, pat, &perrow_opts);
            });

            // packed linalg, per-row walk (the pre-§Perf-L4 baseline)
            kernel::set_naive_mode(false);
            let _warm = run(&w, &stats, pat, &perrow_opts);
            let secs_perrow = best_of(reps, || {
                run(&w, &stats, pat, &perrow_opts);
            });

            // Λ-panel walk
            let p_panel = run(&w, &stats, pat, &panel_opts);
            let secs_panel = best_of(reps, || {
                run(&w, &stats, pat, &panel_opts);
            });

            // divergence gate vs the naive oracle (see module docs)
            let (bad_rows, rel) = cross_check(&p_panel, &p_naive, c, b);
            if bad_rows == 0 {
                assert!(
                    rel <= 1e-5,
                    "{key} c{c}xb{b}: panel diverged from the naive oracle: rel {rel:.3e}"
                );
            } else {
                let tie_flip_possible = !quick
                    && (c, b, a) == largest
                    && matches!(pat, Pattern::Unstructured { .. });
                assert!(
                    tie_flip_possible,
                    "{key} c{c}xb{b}: {bad_rows} rows with diverged masks (only the largest \
                     unstructured full shape may boundary-tie flip)"
                );
                // boundary-tie fallback: the two walks are different
                // (equally valid) prunes — same exact sparsity, and
                // reconstruction quality must agree closely
                assert_eq!(masked(&p_panel), masked(&p_naive), "{key}: sparsity diverged");
                let lp = thanos::linalg::gemm::recon_loss(&p_panel.w, &w, &x);
                let ln = thanos::linalg::gemm::recon_loss(&p_naive.w, &w, &x);
                assert!(
                    (lp - ln).abs() <= 0.02 * ln.max(1e-12),
                    "{key}: quality diverged after tie flip: {lp} vs {ln}"
                );
            }

            let sp_naive = secs_naive / secs_panel.max(1e-12);
            let sp_perrow = secs_perrow / secs_panel.max(1e-12);
            println!(
                "{key:>12} c={c} b={b}: naive {secs_naive:>8.3}s  per-row {secs_perrow:>8.3}s  \
                 panel {secs_panel:>8.3}s  {sp_perrow:>5.2}x vs per-row  rel {rel:.1e}"
            );
            bj.record(
                &format!("prune_e2e/{key}/c{c}xb{b}"),
                vec![
                    ("secs_naive", BenchJson::num(secs_naive)),
                    ("secs_perrow", BenchJson::num(secs_perrow)),
                    ("secs_panel", BenchJson::num(secs_panel)),
                    ("speedup_vs_perrow", BenchJson::num(sp_perrow)),
                    ("speedup_vs_naive", BenchJson::num(sp_naive)),
                    ("rel_err_vs_naive", BenchJson::num(rel)),
                    ("mask_mismatch_rows", BenchJson::num(bad_rows as f64)),
                    ("block_size", BenchJson::num(block as f64)),
                ],
            );
            // perf gate, full mode only (quick/CI shapes are too small
            // to amortize packing — they gate correctness alone). The
            // 2:4 and structured walks ride the row_losses/Δ GEMMs to
            // large wins; the unstructured walk is selection/solve
            // bound (see DESIGN.md §Perf-L4), so it only gates against
            // regression.
            if !quick && (c, b, a) == largest {
                match pat {
                    Pattern::Unstructured { .. } => assert!(
                        sp_perrow >= 0.9,
                        "{key} c{c}xb{b}: panel regressed: {sp_perrow:.2}x"
                    ),
                    _ => assert!(
                        sp_perrow >= 2.0,
                        "{key} c{c}xb{b}: panel speedup {sp_perrow:.2}x < 2x over per-row"
                    ),
                }
            }
        }
    }
    bj.save();
    println!("\nnaive-path cross-check: OK");
}
