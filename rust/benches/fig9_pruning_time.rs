//! Fig. 9 — pruning wall-clock vs model size, per method, for
//! unstructured 50% and structured 30% sparsity.
//!
//! The paper times OPT-family models on an A100; here the same layer
//! suites run on CPU (DESIGN.md §Substitutions — the claim under test
//! is the *crossover shape*, a property of the algorithms' FLOP
//! structure, not the device):
//!
//! * structured: Thanos (closed-form joint solve) is faster than
//!   SparseGPT-structured and scales better;
//! * unstructured: paper-faithful Thanos (O(b⁴/B)) loses to SparseGPT
//!   as size grows (the paper's Fig. 9a crossover), while the fast
//!   suffix-factor mode stays competitive.
//!
//! One "model" = the six distinct prunable layer shapes of one
//! transformer block, scaled by the block count (total-model estimate).

mod common;
use common::*;
use thanos::engine;
use thanos::linalg::Mat;
use thanos::pruning::{self, CalibStats, Method, Pattern, PruneOpts};

struct OptModel {
    name: &'static str,
    d: usize,
    ff: usize,
    n_blocks: usize,
}

/// Marker env var: set by the parent bench process when it re-executes
/// itself with `THANOS_THREADS=1` for the engine-scaling comparison.
const CHILD_ENV: &str = "THANOS_FIG9_CHILD";

fn fnv1a64(h: &mut u64, bytes: &[u8]) {
    for &byte in bytes {
        *h ^= byte as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

/// Whole-model pruning through the engine: the six layer shapes of one
/// block pruned layer-parallel (Thanos unstructured 50%, fast mode).
/// Returns the prune wall seconds, an FNV-1a checksum over the pruned
/// weight bits + masks (bit-identical across thread counts by design),
/// and the engine-counter delta scoped to the prune call alone (the
/// calibration setup is excluded from both the wall time and the
/// counters so the readout describes the pruning it claims to measure).
fn whole_model_suite(d: usize, ff: usize, tokens: usize) -> (f64, u64, engine::EngineStats) {
    let (_, stats_d, _) = bench_layer(8, d, tokens.max(d / 2), 7);
    let (_, stats_ff, _) = bench_layer(8, ff, tokens.max(ff / 2), 8);
    let shapes = [(d, d), (d, d), (d, d), (d, d), (ff, d), (d, ff)];
    let ws: Vec<Mat> = shapes
        .iter()
        .map(|&(c, b)| {
            let mut r = thanos::rng::Rng::new((c * 31 + b) as u64);
            Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0))
        })
        .collect();
    let layers: Vec<(&Mat, &CalibStats)> = ws
        .iter()
        .zip(shapes.iter())
        .map(|(w, &(_c, b))| (w, if b == d { &stats_d } else { &stats_ff }))
        .collect();
    let opts = PruneOpts { block_size: 128, ..Default::default() };
    let stats0 = engine::global().stats();
    let t0 = std::time::Instant::now();
    let results =
        pruning::prune_many(&layers, Method::Thanos, Pattern::Unstructured { p: 0.5 }, &opts);
    let secs = t0.elapsed().as_secs_f64();
    let delta = engine::global().stats().delta_since(&stats0);
    let mut checksum = 0xcbf2_9ce4_8422_2325u64;
    for res in results {
        let (pruned, _) = res.expect("suite prune failed");
        for v in &pruned.w.data {
            fnv1a64(&mut checksum, &v.to_bits().to_le_bytes());
        }
        for &m in &pruned.mask {
            fnv1a64(&mut checksum, &[m as u8]);
        }
    }
    (secs, checksum, delta)
}

fn engine_scaling_section(csv_tokens: usize, bj: &mut BenchJson) {
    let d = env_usize("THANOS_FIG9_SCALE_D", if quick_mode() { 256 } else { 512 });
    println!("== engine scaling: whole-model suite, layer-parallel (d={d}) ==");
    let (par_secs, par_sum, st) = whole_model_suite(d, 4 * d, csv_tokens);
    println!(
        "  parallel:      {par_secs:>6.2}s on {} threads ({} jobs, {} inline, {} tasks, \
         queue peak {}, {:.0}% occupancy)",
        st.threads,
        st.jobs_submitted,
        st.jobs_inline,
        st.tasks_executed,
        st.queue_peak,
        st.occupancy(par_secs) * 100.0
    );
    let child = std::env::current_exe().ok().and_then(|exe| {
        std::process::Command::new(exe)
            .env(engine::THREADS_ENV, "1")
            .env(CHILD_ENV, "1")
            .output()
            .ok()
    });
    let parsed = child.filter(|out| out.status.success()).and_then(|out| {
        let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
        stdout.lines().find_map(|line| {
            let rest = line.strip_prefix("ENGINE_SCALING secs=")?;
            let (secs, sum) = rest.split_once(" checksum=")?;
            Some((secs.parse::<f64>().ok()?, u64::from_str_radix(sum.trim(), 16).ok()?))
        })
    });
    match parsed {
        Some((ser_secs, ser_sum)) => {
            let speedup = ser_secs / par_secs.max(1e-9);
            let identical = ser_sum == par_sum;
            println!(
                "  single-thread: {ser_secs:>6.2}s -> {speedup:.2}x speedup, pruned weights {}",
                if identical { "bit-identical" } else { "DIFFER (determinism bug!)" }
            );
            let mut csv = Csv::new("fig9_engine_scaling");
            let header = "d,threads,parallel_secs,serial_secs,speedup,bit_identical";
            csv.row(
                header,
                &format!(
                    "{},{},{:.3},{:.3},{:.2},{}",
                    d, st.threads, par_secs, ser_secs, speedup, identical
                ),
            );
            println!("  wrote bench_results/fig9_engine_scaling.csv");
            bj.record(
                &format!("fig9_engine_scaling/d{d}"),
                vec![
                    ("threads", BenchJson::num(st.threads as f64)),
                    ("parallel_secs", BenchJson::num(par_secs)),
                    ("serial_secs", BenchJson::num(ser_secs)),
                    ("speedup", BenchJson::num(speedup)),
                    ("bit_identical", thanos::jsonutil::Json::Bool(identical)),
                ],
            );
        }
        None => println!(
            "  (single-thread child run unavailable; rerun with THANOS_THREADS=1 to compare)"
        ),
    }
}

fn main() {
    if std::env::var(CHILD_ENV).is_ok() {
        // child mode: run ONLY the whole-model suite (the parent set
        // THANOS_THREADS=1) and report time + weight checksum
        let d = env_usize("THANOS_FIG9_SCALE_D", if quick_mode() { 256 } else { 512 });
        let tokens = env_usize("THANOS_FIG9_TOKENS", if quick_mode() { 128 } else { 512 });
        let (secs, checksum, _) = whole_model_suite(d, 4 * d, tokens);
        println!("ENGINE_SCALING secs={secs:.6} checksum={checksum:016x}");
        return;
    }
    // OPT family architectural shapes (Zhang et al., 2022)
    let all = [
        OptModel { name: "OPT-125M", d: 768, ff: 3072, n_blocks: 12 },
        OptModel { name: "OPT-350M", d: 1024, ff: 4096, n_blocks: 24 },
        OptModel { name: "OPT-1.3B", d: 2048, ff: 8192, n_blocks: 24 },
    ];
    // THANOS_BENCH_QUICK=1: one model, fewer calibration tokens
    let max_d = env_usize("THANOS_FIG9_MAXD", if quick_mode() { 768 } else { 1024 });
    let models: Vec<&OptModel> = all.iter().filter(|m| m.d <= max_d).collect();
    let a = env_usize("THANOS_FIG9_TOKENS", if quick_mode() { 128 } else { 512 });
    let mut bj = BenchJson::open();
    let mut csv = Csv::new("fig9_pruning_time");
    let header = "model,method,pattern,block_secs,model_secs_est";

    println!("== Fig. 9: pruning time per transformer block (CPU) ==");
    println!("(model estimate = block suite time x n_blocks)\n");

    for m in &models {
        let shapes = [
            (m.d, m.d),
            (m.d, m.d),
            (m.d, m.d),
            (m.d, m.d),
            (m.ff, m.d),
            (m.d, m.ff),
        ];
        // calibration stats once per distinct input dim
        println!("-- {} (d={}, ff={}, {} blocks) --", m.name, m.d, m.ff, m.n_blocks);
        let mk = |b: usize| {
            let (_, stats, _) = bench_layer(8, b, a.max(b / 2), 7);
            stats
        };
        let stats_d = mk(m.d);
        let stats_ff = mk(m.ff);

        type Runner<'s> = Box<dyn Fn(&thanos::linalg::Mat, &thanos::pruning::CalibStats) + 's>;
        let variants: Vec<(&str, &str, Runner)> = vec![
            ("Wanda", "unstr50", Box::new(|w, s| {
                pruning::wanda::unstructured(w, s, 0.5);
            })),
            ("SparseGPT", "unstr50", Box::new(|w, s| {
                let o = PruneOpts { block_size: 128, ..Default::default() };
                pruning::sparsegpt::unstructured(w, s, 0.5, &o).unwrap();
            })),
            ("Thanos(paper)", "unstr50", Box::new(|w, s| {
                let o = PruneOpts {
                    block_size: 128,
                    paper_faithful_inverse: true,
                    ..Default::default()
                };
                pruning::thanos::unstructured(w, s, 0.5, &o).unwrap();
            })),
            ("Thanos(fast)", "unstr50", Box::new(|w, s| {
                let o = PruneOpts { block_size: 128, ..Default::default() };
                pruning::thanos::unstructured(w, s, 0.5, &o).unwrap();
            })),
            ("Wanda", "struct30", Box::new(|w, s| {
                pruning::wanda::structured(w, s, 0.3);
            })),
            ("SparseGPT", "struct30", Box::new(|w, s| {
                pruning::sparsegpt::structured(w, s, 0.3, &PruneOpts::default()).unwrap();
            })),
            ("Thanos", "struct30", Box::new(|w, s| {
                pruning::thanos::structured(w, s, 0.3, 0.1, &PruneOpts::default()).unwrap();
            })),
        ];

        for (method, pattern, f) in &variants {
            // the paper-faithful mode is infeasible beyond 350M shapes on
            // CPU — exactly the scaling pathology Fig. 9a illustrates
            if *method == "Thanos(paper)" && m.d > 512 && std::env::var("THANOS_FIG9_FULL").is_err()
            {
                println!("  {method:<14} {pattern:<9} (skipped; O(b4/B) — set THANOS_FIG9_FULL=1)");
                continue;
            }
            let mut total = 0.0;
            for &(c, b) in &shapes {
                let stats = if b == m.d { &stats_d } else { &stats_ff };
                let mut r = thanos::rng::Rng::new((c * 31 + b) as u64);
                let w = thanos::linalg::Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
                let (_, secs) = time_s(|| f(&w, stats));
                total += secs;
            }
            let est = total * m.n_blocks as f64;
            println!(
                "  {method:<14} {pattern:<9} block {total:>8.2}s   model est {est:>9.1}s"
            );
            csv.row(
                header,
                &format!("{},{},{},{:.3},{:.1}", m.name, method, pattern, total, est),
            );
            bj.record(
                &format!("fig9_pruning_time/{}/{}/{}", m.name, method, pattern),
                vec![
                    ("block_secs", BenchJson::num(total)),
                    ("model_secs_est", BenchJson::num(est)),
                ],
            );
        }
        println!();
    }
    println!("expected shape (paper Fig. 9): structured Thanos fastest of the");
    println!("update methods and flat in size; paper-faithful unstructured Thanos");
    println!("grows ~b^4/B and crosses above SparseGPT as size grows.");
    println!("wrote bench_results/fig9_pruning_time.csv");
    println!();

    // engine-scaling readout: whole-model layer-parallel pruning vs the
    // single-threaded engine setting, with bit-identity verification
    // (disable with THANOS_FIG9_SCALING=0)
    if env_str("THANOS_FIG9_SCALING", "1") != "0" {
        engine_scaling_section(a, &mut bj);
    }
    bj.save();
}
