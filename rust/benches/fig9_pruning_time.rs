//! Fig. 9 — pruning wall-clock vs model size, per method, for
//! unstructured 50% and structured 30% sparsity.
//!
//! The paper times OPT-family models on an A100; here the same layer
//! suites run on CPU (DESIGN.md §Substitutions — the claim under test
//! is the *crossover shape*, a property of the algorithms' FLOP
//! structure, not the device):
//!
//! * structured: Thanos (closed-form joint solve) is faster than
//!   SparseGPT-structured and scales better;
//! * unstructured: paper-faithful Thanos (O(b⁴/B)) loses to SparseGPT
//!   as size grows (the paper's Fig. 9a crossover), while the fast
//!   suffix-factor mode stays competitive.
//!
//! One "model" = the six distinct prunable layer shapes of one
//! transformer block, scaled by the block count (total-model estimate).

mod common;
use common::*;
use thanos::pruning::{self, PruneOpts};

struct OptModel {
    name: &'static str,
    d: usize,
    ff: usize,
    n_blocks: usize,
}

fn main() {
    // OPT family architectural shapes (Zhang et al., 2022)
    let all = [
        OptModel { name: "OPT-125M", d: 768, ff: 3072, n_blocks: 12 },
        OptModel { name: "OPT-350M", d: 1024, ff: 4096, n_blocks: 24 },
        OptModel { name: "OPT-1.3B", d: 2048, ff: 8192, n_blocks: 24 },
    ];
    let max_d = env_usize("THANOS_FIG9_MAXD", 1024);
    let models: Vec<&OptModel> = all.iter().filter(|m| m.d <= max_d).collect();
    let a = env_usize("THANOS_FIG9_TOKENS", 512); // calib tokens per layer
    let mut csv = Csv::new("fig9_pruning_time");
    let header = "model,method,pattern,block_secs,model_secs_est";

    println!("== Fig. 9: pruning time per transformer block (CPU) ==");
    println!("(model estimate = block suite time x n_blocks)\n");

    for m in &models {
        let shapes = [
            (m.d, m.d),
            (m.d, m.d),
            (m.d, m.d),
            (m.d, m.d),
            (m.ff, m.d),
            (m.d, m.ff),
        ];
        // calibration stats once per distinct input dim
        println!("-- {} (d={}, ff={}, {} blocks) --", m.name, m.d, m.ff, m.n_blocks);
        let mk = |b: usize| {
            let (_, stats, _) = bench_layer(8, b, a.max(b / 2), 7);
            stats
        };
        let stats_d = mk(m.d);
        let stats_ff = mk(m.ff);

        type Runner<'s> = Box<dyn Fn(&thanos::linalg::Mat, &thanos::pruning::CalibStats) + 's>;
        let variants: Vec<(&str, &str, Runner)> = vec![
            ("Wanda", "unstr50", Box::new(|w, s| {
                pruning::wanda::unstructured(w, s, 0.5);
            })),
            ("SparseGPT", "unstr50", Box::new(|w, s| {
                let o = PruneOpts { block_size: 128, ..Default::default() };
                pruning::sparsegpt::unstructured(w, s, 0.5, &o).unwrap();
            })),
            ("Thanos(paper)", "unstr50", Box::new(|w, s| {
                let o = PruneOpts {
                    block_size: 128,
                    paper_faithful_inverse: true,
                    ..Default::default()
                };
                pruning::thanos::unstructured(w, s, 0.5, &o).unwrap();
            })),
            ("Thanos(fast)", "unstr50", Box::new(|w, s| {
                let o = PruneOpts { block_size: 128, ..Default::default() };
                pruning::thanos::unstructured(w, s, 0.5, &o).unwrap();
            })),
            ("Wanda", "struct30", Box::new(|w, s| {
                pruning::wanda::structured(w, s, 0.3);
            })),
            ("SparseGPT", "struct30", Box::new(|w, s| {
                pruning::sparsegpt::structured(w, s, 0.3, &PruneOpts::default()).unwrap();
            })),
            ("Thanos", "struct30", Box::new(|w, s| {
                pruning::thanos::structured(w, s, 0.3, 0.1, &PruneOpts::default()).unwrap();
            })),
        ];

        for (method, pattern, f) in &variants {
            // the paper-faithful mode is infeasible beyond 350M shapes on
            // CPU — exactly the scaling pathology Fig. 9a illustrates
            if *method == "Thanos(paper)" && m.d > 512 && std::env::var("THANOS_FIG9_FULL").is_err()
            {
                println!("  {method:<14} {pattern:<9} (skipped; O(b4/B) — set THANOS_FIG9_FULL=1)");
                continue;
            }
            let mut total = 0.0;
            for &(c, b) in &shapes {
                let stats = if b == m.d { &stats_d } else { &stats_ff };
                let mut r = thanos::rng::Rng::new((c * 31 + b) as u64);
                let w = thanos::linalg::Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
                let (_, secs) = time_s(|| f(&w, stats));
                total += secs;
            }
            let est = total * m.n_blocks as f64;
            println!(
                "  {method:<14} {pattern:<9} block {total:>8.2}s   model est {est:>9.1}s"
            );
            csv.row(
                header,
                &format!("{},{},{},{:.3},{:.1}", m.name, method, pattern, total, est),
            );
        }
        println!();
    }
    println!("expected shape (paper Fig. 9): structured Thanos fastest of the");
    println!("update methods and flat in size; paper-faithful unstructured Thanos");
    println!("grows ~b^4/B and crosses above SparseGPT as size grows.");
    println!("wrote bench_results/fig9_pruning_time.csv");
}
