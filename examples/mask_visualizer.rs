//! Mask visualizer — ASCII renderings of the paper's illustration
//! figures from REAL pruning runs (no artifacts needed; pure Rust):
//!
//! * Fig. 2/8 — Thanos global-residual mask selection, block by block
//! * Fig. 4   — SparseGPT per-block local masks
//! * Fig. 6a  — Wanda row-constrained mask
//! * Fig. 3   — structured pruning with outlier rows (permuted view)
//!
//! `o` = pruned entry, `.` = kept.
//!
//! ```bash
//! cargo run --release --example mask_visualizer
//! ```

use thanos::linalg::Mat;
use thanos::pruning::{self, CalibStats, Method, Pattern, PruneOpts};
use thanos::rng::Rng;

fn render(title: &str, mask: &[bool], rows: usize, cols: usize) {
    println!("-- {title} --");
    for i in 0..rows {
        let line: String = (0..cols)
            .map(|j| if mask[i * cols + j] { 'o' } else { '.' })
            .collect();
        println!("  {line}");
    }
    let cnt = mask.iter().filter(|&&m| m).count();
    println!("  ({cnt}/{} pruned = {:.0}%)\n", rows * cols, 100.0 * cnt as f64 / (rows * cols) as f64);
}

fn main() -> anyhow::Result<()> {
    let (c, b) = (12, 32);
    let mut r = Rng::new(7);
    let w = Mat::from_fn(c, b, |_, _| r.normal_f32(0.0, 1.0));
    let x = {
        let mut x = Mat::from_fn(b, 64, |_, _| r.normal_f32(0.0, 1.0));
        // a few dominant input channels → visible vertical structure
        for j in 0..64 {
            *x.at_mut(3, j) *= 4.0;
            *x.at_mut(17, j) *= 0.1;
        }
        x
    };
    let stats = CalibStats::from_x(&x);
    let opts = PruneOpts { block_size: 8, ..Default::default() };
    let p = 0.5;

    println!("weight matrix {c}x{b}, block size {}; 'o' pruned, '.' kept\n", opts.block_size);

    let th = pruning::prune(Method::Thanos, &w, &stats, Pattern::Unstructured { p }, &opts)?;
    render(
        "Thanos (Fig. 2/8): global residual mask — free row/column budget",
        &th.mask, c, b,
    );

    let sg = pruning::prune(Method::SparseGpt, &w, &stats, Pattern::Unstructured { p }, &opts)?;
    render(
        "SparseGPT (Fig. 4): per-block-uniform masks (each 8-col block p% dense)",
        &sg.mask, c, b,
    );

    let wa = pruning::prune(Method::Wanda, &w, &stats, Pattern::Unstructured { p }, &opts)?;
    render(
        "Wanda (Fig. 6a): row-constrained mask (every row exactly p%)",
        &wa.mask, c, b,
    );

    let st = pruning::prune(
        Method::Thanos,
        &w,
        &stats,
        Pattern::Structured { p: 0.25, alpha: 0.2 },
        &opts,
    )?;
    render(
        "Thanos structured (Fig. 3): whole columns; outlier rows (α=0.2) untouched",
        &st.mask, c, b,
    );

    let nm = pruning::prune(
        Method::Thanos,
        &w,
        &stats,
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
        &opts,
    )?;
    render("Thanos 2:4 (Alg. 8): two zeros per group of four", &nm.mask, c, b);
    Ok(())
}
