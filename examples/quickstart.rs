//! Quickstart: the smallest end-to-end use of the library.
//!
//! Trains (or loads a cached) `tiny` LM for a few steps, prunes it with
//! Thanos to 50% unstructured sparsity through the AOT (Pallas/JAX →
//! HLO) pipeline, and reports perplexity before/after next to the
//! Wanda and Magnitude baselines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use thanos::coordinator::Backend;
use thanos::harness::{ensure_trained, env_usize, experiment_corpus, run_cell};
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;

fn main() -> Result<()> {
    let steps = env_usize("THANOS_STEPS", 120);
    let rt = Runtime::load("artifacts")?;
    println!("== thanos quickstart (tiny model, {steps} train steps)");

    let (state, log) = ensure_trained(&rt, "tiny", steps, 2e-3, 1234)?;
    if let (Some(first), Some(last)) = (log.first(), log.last()) {
        println!(
            "trained: loss {:.3} -> {:.3} over {} steps",
            first.loss,
            last.loss,
            log.len()
        );
    } else {
        println!("loaded cached checkpoint");
    }

    let corpus = experiment_corpus(&state.config);
    let dense_ppl = thanos::eval::perplexity(&rt, &state, &corpus.eval)?;
    println!("dense perplexity: {dense_ppl:.3}\n");

    let opts = PruneOpts::default();
    let pattern = Pattern::Unstructured { p: 0.5 };
    println!("pruning to 50% unstructured sparsity:");
    for method in [Method::Magnitude, Method::Wanda, Method::SparseGpt, Method::Thanos] {
        let (cell, _) = run_cell(
            &rt, &state, &corpus, method, pattern, &opts, Backend::Aot, None,
        )?;
        println!(
            "  {:<10} ppl {:>8.3}  (x{:.2} vs dense, sparsity {:.1}%, {:.2}s)",
            method.name(),
            cell.ppl,
            cell.ppl / dense_ppl,
            cell.sparsity * 100.0,
            cell.prune_secs
        );
    }
    println!("\nexpected shape: Thanos ≈ SparseGPT < Wanda << Magnitude");
    Ok(())
}
