//! Semi-structured n:m sparsity (§4.8): prune to 2:4 and 4:8, verify
//! the hardware format exactly (every group of m has ≥ n zeros,
//! respecting α outlier rows), and report the modeled Ampere-style
//! compression/speedup (DESIGN.md §Substitutions).
//!
//! ```bash
//! cargo run --release --example nm_sparsity
//! ```

use anyhow::Result;
use thanos::coordinator::Backend;
use thanos::harness::*;
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;

fn main() -> Result<()> {
    let model = env_str("THANOS_MODEL", "tiny");
    let steps = env_usize("THANOS_STEPS", 120);
    let rt = Runtime::load("artifacts")?;
    let (state, _) = ensure_trained(&rt, &model, steps, 2e-3, 1234)?;
    let corpus = experiment_corpus(&state.config);
    let dense_ppl = thanos::eval::perplexity(&rt, &state, &corpus.eval)?;
    println!("== n:m semi-structured pruning ({model}) — dense ppl {dense_ppl:.3} ==\n");

    let opts = PruneOpts { block_size: 128, ..Default::default() };
    for &(n, m) in &[(4usize, 8usize), (2, 4)] {
        println!("-- {n}:{m} --");
        for &alpha in &[0.0, 0.1] {
            let pattern = Pattern::SemiStructured { n, m, alpha };
            let mut st = state.clone();
            let spec = thanos::coordinator::PruneSpec {
                method: Method::Thanos,
                pattern,
                opts,
                backend: Backend::Aot,
            };
            let report = thanos::coordinator::Coordinator::new(&rt)
                .prune_model(&mut st, &corpus.calib, &spec)?;
            let ppl = thanos::eval::perplexity(&rt, &st, &corpus.eval)?;

            // verify the hardware format on every pruned layer
            let n_outlier = (alpha * state.config.d_model as f64).ceil() as usize;
            let mut verified = 0;
            for l in 0..st.config.n_layers {
                for lname in st.prunable_layers(l) {
                    let w = st.get_mat(&lname)?;
                    // outlier rows are data-dependent; with α>0 just
                    // require the right NUMBER of valid rows
                    let bad_rows: Vec<usize> = (0..w.rows)
                        .filter(|&i| {
                            (0..w.cols).step_by(m).any(|g| {
                                w.row(i)[g..g + m].iter().filter(|&&v| v == 0.0).count() < n
                            })
                        })
                        .collect();
                    let allowed = ((alpha * w.rows as f64).ceil()) as usize;
                    anyhow::ensure!(
                        bad_rows.len() <= allowed,
                        "{lname}: {} rows violate {n}:{m}, allowed {allowed}",
                        bad_rows.len()
                    );
                    verified += 1;
                }
            }
            println!(
                "  α={alpha:<4} ppl {:>8.3} (x{:.2})  sparsity {:>5.1}%  format OK on {verified} layers{}",
                ppl,
                ppl / dense_ppl,
                report.overall_sparsity() * 100.0,
                if alpha > 0.0 {
                    format!(" (≤{n_outlier} outlier rows exempt/layer)")
                } else {
                    String::new()
                }
            );

            // real compressed execution: pack every pruned layer
            // (coordinator-chosen format) and measure the CPU kernels
            if alpha == 0.0 {
                let sm = report.sparse_model(&st)?;
                print!("{}", thanos::eval::compression_report(&st, &sm)?);
            }
        }
        print!("{}", thanos::eval::nm_report(&state, n, m));
        // measured CPU speedup of the zero-skipping GEMM on one layer
        {
            let name = state.prunable_layers(0).pop().unwrap();
            let dense = state.get_mat(&name)?;
            let sp = {
                let stats = {
                    let mut r = thanos::rng::Rng::new(9);
                    let x = thanos::linalg::Mat::from_fn(dense.cols, 256, |_, _| {
                        r.normal_f32(0.0, 1.0)
                    });
                    thanos::pruning::CalibStats::from_x(&x)
                };
                thanos::pruning::thanos::semi_structured(&dense, &stats, n, m, 0.0, &opts)?.w
            };
            let (d_s, s_s) = thanos::eval::measured_sparse_speedup(&dense, &sp, 512);
            println!(
                "  measured CPU zero-skip GEMM on {name}: dense {:.2}ms -> sparse {:.2}ms ({:.2}x)",
                d_s * 1e3,
                s_s * 1e3,
                d_s / s_s
            );
        }
        println!();
    }
    println!("expected shape: 4:8 degrades less than 2:4; α=0.1 helps both;");
    println!("Thanos n:m ≈ SparseGPT n:m at α=0, clearly better at α=0.1 (Table 2).");
    Ok(())
}
