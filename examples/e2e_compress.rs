//! End-to-end driver — the system-level validation run recorded in
//! EXPERIMENTS.md: proves all three layers compose on a real (small)
//! workload.
//!
//! 1. builds the synthetic corpus (train / calib / eval splits),
//! 2. **trains** the `small` (~4.9M param) transformer for a few
//!    hundred steps through the AOT Adam train-step executable,
//!    logging the loss curve,
//! 3. **prunes** the trained checkpoint with every method × every
//!    sparsity pattern of the paper's Table 2 grid through the
//!    coordinator pipeline (Alg. 3),
//! 4. **evaluates** held-out perplexity + the 7-task zero-shot suite
//!    for every cell, and prints the Table-2/3 analogue.
//!
//! ```bash
//! make artifacts MODELS=tiny,small
//! cargo run --release --example e2e_compress               # full (~30 min CPU)
//! THANOS_MODEL=tiny THANOS_STEPS=120 cargo run --release --example e2e_compress  # quick
//! ```

use anyhow::Result;
use thanos::coordinator::Backend;
use thanos::harness::*;
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;
use thanos::train::format_loss_curve;

fn main() -> Result<()> {
    let model = env_str("THANOS_MODEL", "small");
    let steps = env_usize("THANOS_STEPS", 400);
    let zs_n = env_usize("THANOS_ZEROSHOT_N", 40);
    let rt = Runtime::load("artifacts")?;
    let mm = rt.model(&model)?;
    println!(
        "== e2e: train {} ({} params) for {} steps, prune all methods, eval ==",
        model, mm.flat_size, steps
    );

    // ---- train ----------------------------------------------------------
    let t0 = std::time::Instant::now();
    let (state, log) = ensure_trained(&rt, &model, steps, 1e-3, 1234)?;
    if log.is_empty() {
        println!("(loaded cached checkpoint)");
    } else {
        println!("loss curve:");
        print!("{}", format_loss_curve(&log, (steps / 12).max(1)));
        println!("trained in {:.1}s", t0.elapsed().as_secs_f64());
    }

    let corpus = experiment_corpus(&state.config);
    let dense_ppl = thanos::eval::perplexity(&rt, &state, &corpus.eval)?;
    let zs_dense = thanos::eval::zero_shot_suite(&rt, &state, &corpus.grammar, zs_n, 1234)?;
    println!(
        "dense: ppl {:.3}, zero-shot avg {:.1}%\n",
        dense_ppl,
        thanos::eval::zero_shot_average(&zs_dense) * 100.0
    );

    // ---- the Table 2/3 grid ----------------------------------------------
    let patterns: Vec<Pattern> = vec![
        Pattern::Unstructured { p: 0.5 },
        Pattern::Structured { p: 0.3, alpha: 0.0 },
        Pattern::Structured { p: 0.3, alpha: 0.1 },
        Pattern::SemiStructured { n: 4, m: 8, alpha: 0.0 },
        Pattern::SemiStructured { n: 4, m: 8, alpha: 0.1 },
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.0 },
        Pattern::SemiStructured { n: 2, m: 4, alpha: 0.1 },
    ];
    let opts = PruneOpts::default();
    let mut cells = Vec::new();
    for &pattern in &patterns {
        for method in [Method::Magnitude, Method::Wanda, Method::SparseGpt, Method::Thanos] {
            // baselines don't take alpha; skip duplicate α-cells for them
            let alpha_cell = matches!(
                pattern,
                Pattern::Structured { alpha, .. } | Pattern::SemiStructured { alpha, .. }
                if alpha > 0.0
            );
            if alpha_cell && method != Method::Thanos {
                continue;
            }
            let t = std::time::Instant::now();
            let (cell, _) = run_cell(
                &rt,
                &state,
                &corpus,
                method,
                pattern,
                &opts,
                Backend::Aot,
                Some(zs_n),
            )?;
            println!(
                "  [{:>6.1}s] {:<10} {:<22} ppl {:>9.3}  zs {:>5.1}%",
                t.elapsed().as_secs_f64(),
                method.name(),
                pattern.label(),
                cell.ppl,
                cell.zero_shot_avg.unwrap_or(0.0) * 100.0
            );
            cells.push(cell);
        }
    }

    println!("\n=== Table 2/3 analogue ({model}, dense ppl {dense_ppl:.3}) ===");
    print!("{}", format_table(dense_ppl, &cells));

    // ---- acceptance-shape check (DESIGN.md) ------------------------------
    let get = |m: Method, pat: &str| {
        cells
            .iter()
            .find(|c| c.method == m && c.pattern.label() == pat)
            .map(|c| c.ppl)
    };
    let mut ok = true;
    if let (Some(th), Some(sg), Some(wa)) = (
        get(Method::Thanos, "structured 30% (α=0)"),
        get(Method::SparseGpt, "structured 30% (α=0)"),
        get(Method::Wanda, "structured 30% (α=0)"),
    ) {
        println!("\nstructured 30%: thanos {th:.2} vs sparsegpt {sg:.2} vs wanda {wa:.2}");
        ok &= th <= sg && sg <= wa * 1.2;
    }
    if let (Some(a0), Some(a1)) = (
        get(Method::Thanos, "structured 30% (α=0)"),
        get(Method::Thanos, "structured 30% (α=0.1)"),
    ) {
        println!("outlier rows: α=0 {a0:.2} vs α=0.1 {a1:.2}");
    }
    println!(
        "\nacceptance shape (Thanos wins structured): {}",
        if ok { "HOLDS" } else { "CHECK EXPERIMENTS.md" }
    );
    Ok(())
}
