//! Structured pruning with outlier-row detection (§4.7.1): sweep the
//! outlier fraction α at fixed total sparsity and watch perplexity
//! improve — the paper's α ablation (Thanos α=0 vs α=0.1 rows of
//! Table 2, generalized to a curve).
//!
//! ```bash
//! cargo run --release --example structured_outliers
//! ```

use anyhow::Result;
use thanos::coordinator::Backend;
use thanos::harness::*;
use thanos::pruning::{Method, Pattern, PruneOpts};
use thanos::runtime::Runtime;

fn main() -> Result<()> {
    let model = env_str("THANOS_MODEL", "tiny");
    let steps = env_usize("THANOS_STEPS", 120);
    let p = 0.3;
    let rt = Runtime::load("artifacts")?;
    let (state, _) = ensure_trained(&rt, &model, steps, 2e-3, 1234)?;
    let corpus = experiment_corpus(&state.config);
    let dense_ppl = thanos::eval::perplexity(&rt, &state, &corpus.eval)?;
    println!("== structured {}% pruning, α sweep ({model}) ==", p * 100.0);
    println!("dense ppl {dense_ppl:.3}\n");
    println!("  {:<8} {:>10} {:>12} {:>14}", "alpha", "ppl", "vs dense", "cols removed");

    let opts = PruneOpts::default();
    for &alpha in &[0.0, 0.05, 0.1, 0.2, 0.3] {
        let pattern = Pattern::Structured { p, alpha };
        let (cell, _report) = run_cell(
            &rt, &state, &corpus, Method::Thanos, pattern, &opts, Backend::Aot, None,
        )?;
        // columns removed per layer = ceil(p*b/(1-alpha))
        let b = state.config.d_model as f64;
        let s = (p * b / (1.0 - alpha)).ceil() as usize;
        println!(
            "  {:<8} {:>10.3} {:>11.2}x {:>14}",
            alpha,
            cell.ppl,
            cell.ppl / dense_ppl,
            format!("{s}/{}", state.config.d_model)
        );
    }

    println!("\nbaselines at α=0 for reference:");
    for method in [Method::Wanda, Method::SparseGpt] {
        let (cell, _) = run_cell(
            &rt,
            &state,
            &corpus,
            method,
            Pattern::Structured { p, alpha: 0.0 },
            &opts,
            Backend::Aot,
            None,
        )?;
        println!("  {:<10} ppl {:>10.3}", method.name(), cell.ppl);
    }
    println!("\nexpected shape: ppl improves as α grows to ~0.1–0.2, then flattens;");
    println!("Thanos(α=0) already beats SparseGPT/Wanda structured.");
    Ok(())
}
